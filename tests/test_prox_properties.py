"""Hypothesis property tests for the proximal operators (system invariants)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep (pip install .[test])")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.core import (
    group_hard_threshold, group_soft_threshold, project_l1_ball, prox_linf,
    soft_threshold, support_from_rows,
)

vec = hnp.arrays(np.float32, hnp.array_shapes(min_dims=1, max_dims=1,
                                              min_side=1, max_side=64),
                 elements=st.floats(-100, 100, width=32))
tau_s = st.floats(0.0, 50.0, width=32)


@settings(max_examples=50, deadline=None)
@given(vec, tau_s)
def test_soft_threshold_properties(v, tau):
    out = np.asarray(soft_threshold(jnp.asarray(v), tau))
    # shrinkage: |out| <= |v|, signs preserved, zero inside the tube
    assert np.all(np.abs(out) <= np.abs(v) + 1e-5)
    assert np.all((out == 0) | (np.sign(out) == np.sign(v)))
    assert np.all(out[np.abs(v) <= tau] == 0)
    np.testing.assert_allclose(np.abs(out[np.abs(v) > tau]),
                               np.abs(v[np.abs(v) > tau]) - tau, rtol=1e-4, atol=1e-4)


@settings(max_examples=50, deadline=None)
@given(vec, st.floats(0.0625, 50.0, width=32))
def test_l1_projection_feasible_and_idempotent(v, r):
    p = np.asarray(project_l1_ball(jnp.asarray(v), r))
    # float32 cumsum error grows with ||v||_1; use a magnitude-aware tol
    tol = 1e-6 * v.size * (r + np.sum(np.abs(v))) + 1e-5
    assert np.sum(np.abs(p)) <= r + tol
    p2 = np.asarray(project_l1_ball(jnp.asarray(p), r))
    np.testing.assert_allclose(p, p2, atol=max(1e-4, tol))
    # projection of a feasible point is itself
    if np.sum(np.abs(v)) <= r:
        np.testing.assert_allclose(p, v, atol=1e-5)


@settings(max_examples=50, deadline=None)
@given(vec, st.floats(0.0625, 50.0, width=32))
def test_l1_projection_is_closest_feasible_point(v, r):
    """Projection must beat naive feasible candidates in distance."""
    p = np.asarray(project_l1_ball(jnp.asarray(v), r))
    l1 = np.sum(np.abs(v))
    if l1 > r:
        scaled = v * (r / l1)  # a feasible competitor
        d_proj = np.sum((v - p) ** 2)
        d_scaled = np.sum((v - scaled) ** 2)
        assert d_proj <= d_scaled * (1 + 1e-5) + 1e-4


@settings(max_examples=50, deadline=None)
@given(vec, tau_s)
def test_prox_linf_moreau_identity(v, tau):
    """prox_{tau||.||_inf}(v) + P_{tau B1}(v) == v (Moreau decomposition)."""
    jv = jnp.asarray(v)
    lhs = np.asarray(prox_linf(jv, tau)) + np.asarray(project_l1_ball(jv, tau))
    np.testing.assert_allclose(lhs, v, atol=1e-4)


mat = hnp.arrays(np.float32, st.tuples(st.integers(1, 32), st.integers(1, 8)),
                 elements=st.floats(-10, 10, width=32).filter(
                     lambda x: x == 0 or abs(x) > 1e-3))


@settings(max_examples=50, deadline=None)
@given(mat, st.floats(0.0, 20.0, width=32))
def test_group_soft_threshold_row_norm_shrinkage(B, tau):
    out = np.asarray(group_soft_threshold(jnp.asarray(B), tau))
    rn_in = np.linalg.norm(B, axis=-1)
    rn_out = np.linalg.norm(out, axis=-1)
    # each row shrunk by exactly tau (or to zero)
    np.testing.assert_allclose(rn_out, np.maximum(rn_in - tau, 0), rtol=1e-3, atol=1e-3)


@settings(max_examples=50, deadline=None)
@given(mat, st.floats(0.0, 20.0, width=32))
def test_group_hard_threshold_keeps_or_kills_rows(B, lam):
    out = np.asarray(group_hard_threshold(jnp.asarray(B), lam))
    rn = np.linalg.norm(B.astype(np.float64), axis=-1)
    clear = np.abs(rn - lam) > 1e-4 * max(lam, 1.0)  # avoid fp boundary ties
    kept = (rn > lam) & clear
    killed = (rn <= lam) & clear
    np.testing.assert_allclose(out[kept], B[kept])
    assert np.all(out[killed] == 0)


@settings(max_examples=30, deadline=None)
@given(mat, st.floats(0.0, 10.0, width=32), st.floats(0.0, 10.0, width=32))
def test_support_monotone_in_threshold(B, l1, l2):
    """\\hat S(Lambda) is monotone decreasing in Lambda."""
    lo, hi = min(l1, l2), max(l1, l2)
    s_lo = np.asarray(support_from_rows(jnp.asarray(B), lo))
    s_hi = np.asarray(support_from_rows(jnp.asarray(B), hi))
    assert np.all(s_hi <= s_lo)
