"""Chaos tier: the resilience layer under a deterministic fault barrage.

Every fault class `repro.testing.faults` can script — poisoned batches
(NaN / Inf / magnitude outburst), forced refit divergence, torn
checkpoint writes — plus the one it cannot (SIGKILL of a live ingest
subprocess) is driven here against the invariants DESIGN.md §15 pins:

* a poisoned chunk leaves `(Sigma, c)` bitwise unchanged and is
  counted in the quarantine ledger + `stream.quarantine{reason}`;
* a divergent refit never replaces the serving model: the generation
  holds, predictions are bitwise the last good model's, the retry is
  scheduled with backoff;
* a truncated checkpoint head still restarts the service, one retained
  generation back;
* SIGKILL mid-ingest leaves a loadable checkpoint store behind;
* the seeded end-to-end schedule (`tools/chaos.py`) reports zero
  invariant violations.

Run via `make test-chaos` (also part of plain pytest discovery).
"""
from __future__ import annotations

import os
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.checkpoint.io import (
    CheckpointError, atomic_write, restore_pytree, save_pytree,
)
from repro.checkpoint.manifest import CheckpointStore
from repro.stream import StreamingDsmlService
from repro.stream.guard import IngestGuard
from repro.substrate import popen_probe
from repro.testing import (
    DivergenceInjector, apply_batch_fault, build_schedule,
    make_clean_batch, truncate_file,
)

LAM, MU, THR = 0.4, 0.2, 1.0


def _service(m=2, p=16, **kw):
    kw.setdefault("lam", LAM)
    kw.setdefault("mu", MU)
    kw.setdefault("Lam", THR)
    return StreamingDsmlService(m, p, **kw)


# -- fault class 1-3: poisoned batches ------------------------------------

@pytest.mark.parametrize("kind", ["nan", "inf", "outlier"])
def test_poisoned_batch_is_quarantined_bitwise(kind):
    rng = np.random.default_rng(3)
    svc = _service(refit_every=10**9,
                   guard=IngestGuard(warmup_chunks=1))
    for _ in range(3):          # healthy traffic arms the outlier gate
        svc.ingest(*make_clean_batch(rng, 2, 32, 16))
    before = (np.asarray(svc.state.Sigmas).copy(),
              np.asarray(svc.state.cs).copy(),
              np.asarray(svc.state.counts).copy())
    quarantined_before = obs.counter_total("stream.quarantine")
    X, y = apply_batch_fault(*make_clean_batch(rng, 2, 32, 16), kind, rng)
    assert svc.ingest(X, y) is None
    after = (np.asarray(svc.state.Sigmas), np.asarray(svc.state.cs),
             np.asarray(svc.state.counts))
    for b, a in zip(before, after):
        assert np.array_equal(b, a)        # bitwise: reject = no fold
    assert svc.guard.total_quarantined == 1
    want_reason = "outlier" if kind == "outlier" else "nonfinite"
    assert svc.guard.ledger[-1].reason == want_reason
    assert obs.counter_total("stream.quarantine") == quarantined_before + 1
    # the stream keeps flowing afterwards
    assert svc.ingest(*make_clean_batch(rng, 2, 32, 16)) is None
    assert svc.guard.accepted == 4


def test_guard_magnitude_ceiling_routes_standalone():
    rng = np.random.default_rng(4)
    svc = _service(guard=IngestGuard(max_abs=50.0), refit_every=10**9)
    svc.ingest(*make_clean_batch(rng, 2, 32, 16))
    X, y = make_clean_batch(rng, 2, 32, 16)
    X = X.at[0, 0, 0].set(1e3)
    assert svc.ingest(X, y) is None
    assert svc.guard.ledger[-1].reason == "magnitude"
    assert svc.guard.accepted == 1


def test_quarantine_ledger_is_bounded():
    g = IngestGuard(ledger_capacity=4)
    rng = np.random.default_rng(5)
    X, y = apply_batch_fault(*make_clean_batch(rng, 1, 8, 8), "nan", rng)
    for _ in range(7):
        ok, reason = g.admit(X, y)
        assert (ok, reason) == (False, "nonfinite")
    assert len(g.ledger) == 4
    assert g.dropped_records == 3
    assert g.total_quarantined == 7


# -- fault class 4: refit divergence --------------------------------------

def test_forced_divergent_refit_rolls_back_and_recovers():
    rng = np.random.default_rng(6)
    svc = _service(refit_every=64, guard=False)
    svc.ingest(*make_clean_batch(rng, 2, 64, 16))      # triggers refit
    assert svc.generation == 1
    Xp = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    good_pred = np.asarray(svc.predict(Xp))

    inj = DivergenceInjector(svc)
    inj.arm(1)
    info = svc.refit()
    assert inj.injected == 1
    assert svc.generation == 1                 # rollback kept last good
    assert int(info.generation) == 1
    assert svc.rollbacks == 1
    assert svc.last_health is not None and not svc.last_health.healthy
    assert svc.last_health.reason == "nonfinite_model"
    assert svc._interval == 2 * 64             # capped exponential backoff
    assert np.array_equal(np.asarray(svc.predict(Xp)), good_pred)

    info = svc.refit()                         # escalated retry, healthy
    inj.uninstall()
    assert svc.generation == 2
    assert svc._refit_failures == 0
    assert svc._interval == 64                 # cadence back to base
    assert np.isfinite(np.asarray(svc.predict(Xp))).all()


def test_backoff_caps_at_max_refit_interval():
    svc = _service(refit_every=64, max_refit_interval=256, guard=False)
    rng = np.random.default_rng(7)
    svc.ingest(*make_clean_batch(rng, 2, 64, 16))
    inj = DivergenceInjector(svc)
    inj.arm(5)
    for want in (128, 256, 256, 256, 256):     # 64*2^k capped at 256
        svc.refit()
        assert svc._interval == want
    assert svc.generation == 1
    assert svc.rollbacks == 5
    inj.uninstall()


# -- fault class 5: torn checkpoints --------------------------------------

def _stamped_tree(svc, generation):
    svc.state = svc.state._replace(
        generation=jnp.asarray(generation, jnp.int32))
    return svc._ckpt_tree()


def test_truncated_head_falls_back_one_generation(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    svc = _service(guard=False)
    for g in (1, 2, 3):
        store.save(_stamped_tree(svc, g), g)
    assert store.generations() == [3, 2, 1]
    truncate_file(str(tmp_path / "ckpt_00000003.npz"), keep_fraction=0.4)
    tree, gen = store.load(svc._ckpt_tree())
    assert gen == 2
    assert int(tree["state"].generation) == 2
    assert obs.counter_total("checkpoint.fallback", reason="checksum") >= 1


def test_corrupt_manifest_degrades_to_directory_scan(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=3)
    svc = _service(guard=False)
    for g in (1, 2):
        store.save(_stamped_tree(svc, g), g)
    (tmp_path / "MANIFEST.json").write_text("{ not json")
    tree, gen = store.load(svc._ckpt_tree())
    assert gen == 2             # head intact, found without the manifest
    # a truncated head is still skipped (restore error, not checksum)
    truncate_file(str(tmp_path / "ckpt_00000002.npz"), keep_fraction=0.2)
    tree, gen = store.load(svc._ckpt_tree())
    assert gen == 1


def test_store_prunes_to_keep(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    svc = _service(guard=False)
    for g in range(1, 6):
        store.save(_stamped_tree(svc, g), g)
    assert store.generations() == [5, 4]
    names = sorted(f for f in os.listdir(tmp_path) if f.endswith(".npz"))
    assert names == ["ckpt_00000004.npz", "ckpt_00000005.npz"]


def test_all_generations_corrupt_raises(tmp_path):
    store = CheckpointStore(str(tmp_path), keep=2)
    svc = _service(guard=False)
    for g in (1, 2):
        store.save(_stamped_tree(svc, g), g)
    for name in ("ckpt_00000001.npz", "ckpt_00000002.npz"):
        truncate_file(str(tmp_path / name), keep_fraction=0.1)
    with pytest.raises(CheckpointError, match="no loadable checkpoint"):
        store.load(svc._ckpt_tree())


def test_atomic_save_failure_keeps_previous(tmp_path):
    path = str(tmp_path / "ck.npz")
    save_pytree(path, {"a": jnp.arange(4.0)})

    def boom(f):
        f.write(b"partial garbage")
        raise RuntimeError("simulated crash mid-write")

    with pytest.raises(RuntimeError, match="simulated crash"):
        atomic_write(path, boom)
    restored = restore_pytree(path, {"a": jnp.zeros(4)})   # still intact
    assert np.array_equal(np.asarray(restored["a"]), [0, 1, 2, 3])
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []


def test_service_load_validates_compat(tmp_path):
    svc = _service(m=2, p=16, guard=False)
    path = str(tmp_path / "svc.npz")
    svc.save(path)
    wrong_p = _service(m=2, p=32, guard=False)
    with pytest.raises(CheckpointError, match="incompatible"):
        wrong_p.load(path)
    wrong_m = _service(m=4, p=16, guard=False)
    with pytest.raises(CheckpointError, match="incompatible"):
        wrong_m.load(path)
    # f16 lands on disk as f16 (unlike bf16's f32 upcast), so it is a
    # genuine on-disk dtype mismatch against the f32 checkpoint
    wrong_dt = _service(m=2, p=16, dtype=jnp.float16, guard=False)
    with pytest.raises(CheckpointError, match="dtype"):
        wrong_dt.load(path)
    with pytest.raises(CheckpointError, match="not a StreamingDsmlService"):
        save_pytree(str(tmp_path / "other.npz"), {"weights": jnp.zeros(3)})
        svc.load(str(tmp_path / "other.npz"))
    svc2 = _service(m=2, p=16, guard=False)
    svc2.load(path)             # the compatible load still works
    assert svc2.generation == svc.generation


def test_service_checkpoint_restore_cycle(tmp_path):
    rng = np.random.default_rng(8)
    # max_refit_interval=32 pins the cadence: the drift-adaptive widen
    # must not skip refits here, every chunk commits a generation
    svc = _service(refit_every=32, max_refit_interval=32, guard=False,
                   ckpt_dir=str(tmp_path), ckpt_keep=2)
    for _ in range(3):
        svc.ingest(*make_clean_batch(rng, 2, 32, 16))
    assert svc.generation == 3
    assert svc.ckpt_store.generations() == [3, 2]
    truncate_file(str(tmp_path / "ckpt_00000003.npz"), keep_fraction=0.3)
    fresh = _service(refit_every=32, guard=False, ckpt_dir=str(tmp_path))
    assert fresh.restore() == 2
    assert fresh.generation == 2
    Xp = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    assert np.isfinite(np.asarray(fresh.predict(Xp))).all()


# -- fault class 6: SIGKILL mid-ingest ------------------------------------

_KILL_PAYLOAD = """
import numpy as np
from repro.stream import StreamingDsmlService
from repro.testing import make_clean_batch

svc = StreamingDsmlService(2, 16, lam=0.4, mu=0.2, Lam=1.0,
                           refit_every=32, guard=False,
                           ckpt_dir={ckpt_dir!r})
rng = np.random.default_rng(0)
for step in range(100000):
    svc.ingest(*make_clean_batch(rng, 2, 32, 16))
    print("gen", svc.generation, flush=True)
"""


def test_sigkill_mid_ingest_leaves_loadable_store(tmp_path):
    ckpt_dir = str(tmp_path / "store")
    proc = popen_probe(_KILL_PAYLOAD.format(ckpt_dir=ckpt_dir),
                       n_devices=1)
    manifest = os.path.join(ckpt_dir, "MANIFEST.json")

    def _retained() -> int:
        # tolerate reading the manifest concurrently with the child's
        # atomic rewrites — a failed read counts as "not yet"
        import json
        try:
            with open(manifest) as f:
                return len(json.load(f)["checkpoints"])
        except (OSError, ValueError, KeyError):
            return 0

    try:
        deadline = time.time() + 300
        # wait until the child has committed at least two generations,
        # so it dies mid-stream with retained history behind it
        while time.time() < deadline:
            if _retained() >= 2:
                break
            if proc.poll() is not None:
                out, err = proc.communicate()
                pytest.fail(f"ingest child died early:\n{err}")
            time.sleep(0.2)
        else:
            pytest.fail("child never wrote two checkpoint generations")
    finally:
        proc.kill()             # SIGKILL: no atexit, no cleanup
        proc.communicate()
    svc = _service(guard=False, ckpt_dir=ckpt_dir)
    gen = svc.restore()
    assert gen >= 2
    assert np.isfinite(np.asarray(svc.state.Sigmas)).all()


# -- the seeded end-to-end schedule ---------------------------------------

def test_seeded_schedule_holds_all_invariants(tmp_path):
    import tools.chaos as chaos
    report = chaos.run_schedule(seed=7, steps=24,
                                ckpt_dir=str(tmp_path / "store"))
    assert report["failures"] == []
    assert report["poisoned"] >= 4             # >= 4 fault events fired
    assert len(report["schedule"]) >= 3        # across >= 3 fault classes
    assert report["rollbacks"] >= 1            # divergence class fired
    assert report["restore"] is not None       # truncation class fired


def test_schedule_is_deterministic():
    a = build_schedule(40, 123, per_kind=3, start=2)
    b = build_schedule(40, 123, per_kind=3, start=2)
    assert a == b
    assert all(2 <= ev.step < 40 for ev in a.events)
    assert a.by_kind() == {"nan": 3, "inf": 3, "outlier": 3}
