"""Unit tests for the DSML core solvers (lasso / group lasso / iCAP / debias)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ar_covariance, coherence, debias_lasso, dsml_fit, estimation_error,
    gen_regression, group_lasso, hamming, icap, inverse_hessian_m, lasso,
    power_iteration, refit_ols_masked, support_of,
)

KEY = jax.random.PRNGKey(0)


def test_power_iteration_matches_eigh():
    A = jax.random.normal(KEY, (50, 50))
    S = A @ A.T / 50
    lam = power_iteration(S, iters=200)
    np.testing.assert_allclose(float(lam), float(jnp.linalg.eigvalsh(S)[-1]), rtol=1e-4)


def test_lasso_orthogonal_design_closed_form():
    """With X^T X / n = I the lasso solution is soft(beta_ols, lam/2)."""
    n, p = 400, 16
    X = jnp.eye(p).repeat(n // p, axis=0) * jnp.sqrt(p)  # orthonormal cols: X'X/n = I
    key1, key2 = jax.random.split(KEY)
    beta_star = jax.random.normal(key1, (p,))
    y = X @ beta_star
    lam = 0.3
    beta = lasso(X, y, lam, iters=800)
    # objective (1/n)||y-Xb||^2 + lam|b|_1 with X'X/n=I -> soft(b*, lam/2)
    expected = jnp.sign(beta_star) * jnp.maximum(jnp.abs(beta_star) - lam / 2, 0)
    np.testing.assert_allclose(np.asarray(beta), np.asarray(expected), atol=1e-3)


def test_lasso_kkt_conditions():
    data = gen_regression(KEY, m=1, n=80, p=60, s=5)
    X, y = data.Xs[0], data.ys[0]
    lam = 0.2
    b = lasso(X, y, lam, iters=2000)
    n = X.shape[0]
    g = 2.0 / n * (X.T @ (X @ b - y))  # grad of (1/n)||y-Xb||^2
    # KKT: |g_j| <= lam, and g_j = -lam*sign(b_j) where b_j != 0
    assert float(jnp.max(jnp.abs(g))) <= lam * 1.05
    active = jnp.abs(b) > 1e-6
    viol = jnp.where(active, jnp.abs(g + lam * jnp.sign(b)), 0.0)
    assert float(jnp.max(viol)) < 1e-2


def test_group_lasso_recovers_shared_support():
    data = gen_regression(KEY, m=8, n=100, p=100, s=5, signal_low=0.5)
    B = group_lasso(data.Xs, data.ys, 0.25, iters=600)
    assert int(hamming(support_of(B, 1e-3), data.support)) == 0


def test_icap_recovers_shared_support():
    data = gen_regression(KEY, m=8, n=100, p=100, s=5, signal_low=0.5)
    B = icap(data.Xs, data.ys, 0.4, iters=800)
    assert int(hamming(support_of(B, 1e-3), data.support)) == 0


def test_inverse_hessian_feasible_for_jm_constraint():
    """The penalized M must satisfy the paper's constraint ||Sig m_j - e_j||_inf <= mu."""
    data = gen_regression(KEY, m=1, n=120, p=80, s=5)
    X = data.Xs[0]
    Sig = X.T @ X / X.shape[0]
    mu = float(jnp.sqrt(jnp.log(80.0) / 120))
    M = inverse_hessian_m(Sig, mu, iters=1200)
    assert float(coherence(Sig, M)) <= mu * 1.02


def test_debias_reduces_bias_on_support():
    """Debiasing should shrink the lasso bias on true nonzeros."""
    data = gen_regression(jax.random.PRNGKey(3), m=1, n=150, p=100, s=5,
                          signal_low=0.5)
    X, y = data.Xs[0], data.ys[0]
    lam = float(4 * jnp.sqrt(jnp.log(100.0) / 150))
    mu = float(jnp.sqrt(jnp.log(100.0) / 150))
    b_hat = lasso(X, y, lam, iters=1000)
    b_u = debias_lasso(X, y, b_hat, mu)
    S = data.support
    bias_lasso = float(jnp.abs(b_hat - data.B[:, 0])[S].mean())
    bias_debiased = float(jnp.abs(b_u - data.B[:, 0])[S].mean())
    assert bias_debiased < bias_lasso


def test_refit_ols_masked_equals_restricted_ols():
    n, p = 60, 20
    X = jax.random.normal(KEY, (n, p))
    beta = jnp.zeros(p).at[:4].set(jnp.array([1.0, -2.0, 0.5, 3.0]))
    y = X @ beta
    support = jnp.arange(p) < 4
    b = refit_ols_masked(X, y, support)
    np.testing.assert_allclose(np.asarray(b), np.asarray(beta), atol=1e-4)
    assert float(jnp.abs(b[4:]).max()) == 0.0


def test_dsml_exact_support_recovery_with_theory_threshold():
    """End-to-end Algorithm 1 on well-separated data."""
    data = gen_regression(jax.random.PRNGKey(7), m=10, n=100, p=200, s=10,
                          signal_low=0.3, signal_high=1.0)
    n, p = 100, 200
    lam = 4 * jnp.sqrt(jnp.log(float(p)) / n)
    mu = jnp.sqrt(jnp.log(float(p)) / n)
    res = dsml_fit(data.Xs, data.ys, lam, mu, Lam=1.0)
    assert int(hamming(res.support, data.support)) == 0
    # final estimate beats local lasso in l1/l2 error
    err_dsml = float(estimation_error(res.beta_tilde.T, data.B))
    err_lasso = float(estimation_error(res.beta_local.T, data.B))
    assert err_dsml < err_lasso


def test_dsml_refit_variant():
    data = gen_regression(jax.random.PRNGKey(9), m=6, n=120, p=100, s=6,
                          signal_low=0.4)
    lam = 4 * jnp.sqrt(jnp.log(100.0) / 120)
    mu = jnp.sqrt(jnp.log(100.0) / 120)
    res = dsml_fit(data.Xs, data.ys, lam, mu, Lam=1.0, refit=True)
    err = float(estimation_error(res.beta_tilde.T, data.B))
    res_plain = dsml_fit(data.Xs, data.ys, lam, mu, Lam=1.0)
    err_plain = float(estimation_error(res_plain.beta_tilde.T, data.B))
    assert err <= err_plain * 1.05  # refit should not be (much) worse
