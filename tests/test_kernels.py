"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
sweeping shapes and dtypes per kernel (per the kernel contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.group_threshold.ops import group_threshold
from repro.kernels.group_threshold.ref import group_threshold_ref
from repro.kernels.ista_step.ops import ista_solve, ista_step
from repro.kernels.ista_step.ref import ista_step_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ista_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [64, 128, 256, 384])
@pytest.mark.parametrize("r", [1, 8, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ista_step_shapes_dtypes(p, r, dtype):
    A = jax.random.normal(KEY, (p, p), jnp.float32)
    Sigma = (A @ A.T / p).astype(dtype)
    beta = jax.random.normal(jax.random.PRNGKey(1), (p, r), dtype)
    c = jax.random.normal(jax.random.PRNGKey(2), (p, r), dtype)
    out = ista_step(Sigma, beta, c, 0.05, 0.2)
    ref = ista_step_ref(Sigma, beta, c, 0.05, 0.2)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_ista_step_vector_rhs():
    p = 128
    A = jax.random.normal(KEY, (p, p))
    Sigma = A @ A.T / p
    beta = jax.random.normal(jax.random.PRNGKey(1), (p,))
    c = jax.random.normal(jax.random.PRNGKey(2), (p,))
    out = ista_step(Sigma, beta, c, 0.05, 0.2)
    assert out.shape == (p,)
    ref = ista_step_ref(Sigma, beta[:, None], c[:, None], 0.05, 0.2)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_ista_solve_matches_fista_solution():
    """The kernel-driven solver must satisfy the lasso KKT conditions."""
    p = 128
    A = jax.random.normal(KEY, (3 * p, p)) / jnp.sqrt(3.0 * p)
    Sigma = A.T @ A + 0.1 * jnp.eye(p)
    c = jax.random.normal(jax.random.PRNGKey(1), (p, 1)) * 0.3
    lam = 0.05
    beta = ista_solve(Sigma, c, lam, iters=1500)
    g = Sigma @ beta - c                      # subgradient condition
    assert float(jnp.max(jnp.abs(g))) <= lam * 1.05
    active = jnp.abs(beta) > 1e-6
    viol = jnp.where(active, jnp.abs(g + lam * jnp.sign(beta)), 0.0)
    assert float(jnp.max(viol)) < 5e-3


# ---------------------------------------------------------------------------
# group_threshold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,m", [(64, 4), (256, 10), (1024, 16), (200, 10)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_threshold_shapes_dtypes(p, m, dtype):
    B = jax.random.normal(KEY, (p, m), dtype) * 2.0
    out, keep = group_threshold(B, 2.0)
    ref_out, ref_keep = group_threshold_ref(B, 2.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(ref_keep))


def test_group_threshold_edge_lambdas():
    B = jax.random.normal(KEY, (128, 8))
    out0, keep0 = group_threshold(B, 0.0)
    assert bool(jnp.all(keep0))                     # every row has norm > 0
    outinf, keepinf = group_threshold(B, 1e9)
    assert not bool(jnp.any(keepinf))
    assert bool(jnp.all(outinf == 0))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,n,k,h", [(128, 4, 4, 32), (256, 8, 2, 64),
                                     (64, 2, 1, 128), (192, 4, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes_dtypes(s, n, k, h, dtype):
    q = jax.random.normal(KEY, (2, s, n, h), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (2, s, k, h), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, k, h), dtype)
    out = flash_attention_op(q, kk, v, causal=True, bq=64, bk=64)
    ref = flash_attention_ref(q.astype(jnp.float32), kk.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_kernel_sliding_window(window):
    s = 256
    q = jax.random.normal(KEY, (1, s, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, s, 4, 32))
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_kernel_noncausal():
    s = 128
    q = jax.random.normal(KEY, (1, s, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, s, 2, 64))
    out = flash_attention_op(q, k, v, causal=False, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
