"""Pallas kernel tests: interpret-mode execution vs pure-jnp oracles,
sweeping shapes and dtypes per kernel (per the kernel contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention_op
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.group_threshold.ops import group_threshold
from repro.kernels.group_threshold.ref import group_threshold_ref
from repro.kernels.ista_step.ops import ista_solve, ista_step
from repro.kernels.ista_step.ref import ista_step_ref
from repro.kernels.logistic_grad.ops import logistic_grad, logistic_grad_unfused
from repro.kernels.logistic_grad.ref import logistic_grad_ref
from repro.kernels.rank_update.ops import rank_update, rank_update_unfused
from repro.kernels.rank_update.ref import rank_update_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# ista_step
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p", [64, 128, 256, 384])
@pytest.mark.parametrize("r", [1, 8, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ista_step_shapes_dtypes(p, r, dtype):
    A = jax.random.normal(KEY, (p, p), jnp.float32)
    Sigma = (A @ A.T / p).astype(dtype)
    beta = jax.random.normal(jax.random.PRNGKey(1), (p, r), dtype)
    c = jax.random.normal(jax.random.PRNGKey(2), (p, r), dtype)
    out = ista_step(Sigma, beta, c, 0.05, 0.2)
    ref = ista_step_ref(Sigma, beta, c, 0.05, 0.2)
    tol = 1e-5 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_ista_step_vector_rhs():
    p = 128
    A = jax.random.normal(KEY, (p, p))
    Sigma = A @ A.T / p
    beta = jax.random.normal(jax.random.PRNGKey(1), (p,))
    c = jax.random.normal(jax.random.PRNGKey(2), (p,))
    out = ista_step(Sigma, beta, c, 0.05, 0.2)
    assert out.shape == (p,)
    ref = ista_step_ref(Sigma, beta[:, None], c[:, None], 0.05, 0.2)[:, 0]
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_ista_solve_matches_fista_solution():
    """The kernel-driven solver must satisfy the lasso KKT conditions."""
    p = 128
    A = jax.random.normal(KEY, (3 * p, p)) / jnp.sqrt(3.0 * p)
    Sigma = A.T @ A + 0.1 * jnp.eye(p)
    c = jax.random.normal(jax.random.PRNGKey(1), (p, 1)) * 0.3
    lam = 0.05
    beta = ista_solve(Sigma, c, lam, iters=1500)
    g = Sigma @ beta - c                      # subgradient condition
    assert float(jnp.max(jnp.abs(g))) <= lam * 1.05
    active = jnp.abs(beta) > 1e-6
    viol = jnp.where(active, jnp.abs(g + lam * jnp.sign(beta)), 0.0)
    assert float(jnp.max(viol)) < 5e-3


# ---------------------------------------------------------------------------
# logistic_grad (fused all-tasks gradient)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,p,bn", [(1, 64, 32, 16), (3, 96, 48, 32),
                                      (4, 128, 200, 128), (2, 40, 16, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_logistic_grad_shapes_dtypes(m, n, p, bn, dtype):
    Xs = jax.random.normal(KEY, (m, n, p), dtype)
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (m, n))
                  ).astype(dtype)
    B = (jax.random.normal(jax.random.PRNGKey(2), (m, p)) * 0.3
         ).astype(dtype)
    out = logistic_grad(Xs, ys, B, block=bn, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


def test_logistic_grad_unfused_matches_fused():
    m, n, p = 3, 64, 40
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (m, n)))
    B = jax.random.normal(jax.random.PRNGKey(2), (m, p)) * 0.3
    fused = logistic_grad(Xs, ys, B, block=16, interpret=True)
    unfused = logistic_grad_unfused(Xs, ys, B, block=16, interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-6)


def test_logistic_grad_ragged_falls_back_to_oracle():
    """Ragged (n, p) must route to the oracle bitwise — callers never
    pre-check shapes."""
    m, n, p = 2, 33, 17
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (m, n)))
    B = jax.random.normal(jax.random.PRNGKey(2), (m, p))
    out = logistic_grad(Xs, ys, B, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(logistic_grad_ref(Xs, ys, B)))


def _logistic_largep_case(m, n, p, seed=0, scale=0.02):
    Xs = jax.random.normal(jax.random.PRNGKey(seed), (m, n, p))
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(seed + 1), (m, n)))
    B = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, p)) * scale
    return Xs, ys, B


def test_logistic_grad_p8192_executes_on_kernel_path():
    """ISSUE 5 acceptance: p = 8192 (8-aligned n) is past the old
    MAX_FULL_LANE_P cliff but must now run the feature-tiled pallas
    kernel — the default policy picks a real feature tiling (bp < p)
    and matches the oracle to 1e-5."""
    from repro.kernels.logistic_grad.ops import (
        resolve_logistic_blocks, routes_to_oracle,
    )
    m, n, p = 2, 128, 8192
    assert not routes_to_oracle(n, p)
    bn, bp = resolve_logistic_blocks(n, p)
    assert bp < p and p % bp == 0           # genuinely feature-tiled
    Xs, ys, B = _logistic_largep_case(m, n, p)
    out = logistic_grad(Xs, ys, B, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@pytest.mark.parametrize("block", [(64, 1024), (32, 2048), (48, 1024),
                                   (100, 1500)])  # non-divisors included
def test_logistic_grad_p8192_explicit_tilings(block):
    m, n, p = 1, 192, 8192
    Xs, ys, B = _logistic_largep_case(m, n, p, seed=3)
    out = logistic_grad(Xs, ys, B, block=block, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_logistic_grad_unfused_feature_tiled_matches_fused():
    """The two-dispatch twin must tile features identically: same
    (bn, bp), bitwise-equal f32 accumulation order."""
    m, n, p = 2, 64, 8192
    Xs, ys, B = _logistic_largep_case(m, n, p, seed=5)
    fused = logistic_grad(Xs, ys, B, block=(32, 2048), interpret=True)
    unfused = logistic_grad_unfused(Xs, ys, B, block=(32, 2048),
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-6)


@pytest.mark.parametrize("bad", [(16,), (8, 8, 8), "64", 12.5, (8.0, 8),
                                 True, (True, 8), 0, -8, (8, 0), (8, -8)])
def test_logistic_grad_block_validation_raises(bad):
    """The old dispatcher documented `block: int` but silently accepted
    any tuple via block[0]; malformed blocks must raise, never coerce."""
    Xs, ys, B = _logistic_largep_case(1, 16, 16)
    with pytest.raises(TypeError):
        logistic_grad(Xs, ys, B, block=bad, interpret=True)


def test_rank_and_ista_block_validation_raises():
    from repro.kernels.ista_step.ops import resolve_blocks
    Xs = jax.random.normal(KEY, (1, 16, 16))
    ys = jnp.sign(jax.random.normal(KEY, (1, 16)))
    with pytest.raises(TypeError):
        rank_update(Xs, ys, block=(8, 8, 8), interpret=True,
                    use_kernel=True)
    # validation must fire on the oracle path too (use_kernel False is
    # the CPU default) — a malformed block must never defer its crash
    # to the first TPU run
    with pytest.raises(TypeError):
        rank_update(Xs, ys, block=(8, 8, 8), use_kernel=False)
    with pytest.raises(TypeError):
        resolve_blocks(16, 1, (8, 8))       # a rank-style pair
    with pytest.raises(TypeError):
        resolve_blocks(16, 1, "128")
    # ragged shapes (which the oracle serves, ignoring blocks) and the
    # engine's CPU/oracle policies still validate
    from repro.kernels.ista_step.ops import ista_step_batched
    S33 = jax.random.normal(KEY, (1, 33, 33))
    b33 = jax.random.normal(KEY, (1, 33, 1))
    with pytest.raises(TypeError):
        ista_step_batched(S33, b33, b33, jnp.ones((1,)), 0.1, block=(8, 8))
    from repro.core.engine import (
        resolve_block_policy, resolve_logistic_block_policy,
    )
    with pytest.raises(TypeError):
        resolve_block_policy(1, 16, 1, jnp.float32, (8, 8), False)
    with pytest.raises(TypeError):
        resolve_logistic_block_policy(1, 16, 16, jnp.float32, (8, 8, 8),
                                      False)


def test_ista_resolve_blocks_no_sliver_halving():
    """The old local halving clip degraded non-divisor requests to
    single-element tiles (48-on-80 -> 1); the aligned divisor scan
    returns 40."""
    from repro.kernels.ista_step.ops import resolve_blocks
    assert resolve_blocks(80, 1, 48) == (40, 1, 40)
    assert resolve_blocks(384, 8, 128) == (128, 8, 128)


def test_sliver_shapes_route_to_oracle_bitwise():
    """ISSUE 5 regression: n = 1016 = 8*127 has no aligned divisor near
    the default 128 request (the divisor scan finds 127, which breaks
    sublane alignment; the best aligned tile is a sliver of 8). Both
    sample-streaming dispatchers must route it to the oracle instead of
    quietly running a 127-step sliver grid."""
    from repro.kernels.common import (
        aligned_fit_block, degrades_to_slivers, fit_block,
    )
    from repro.kernels.logistic_grad.ops import routes_to_oracle
    from repro.kernels.rank_update.ops import rank_routes_to_oracle
    assert fit_block(1016, 128) == 127      # unaligned: a trap, not a tile
    assert aligned_fit_block(1016, 128) == 8
    assert degrades_to_slivers(1016, 128)
    assert not degrades_to_slivers(80, 48)  # modest clip stays on-kernel
    assert not degrades_to_slivers(1016, 8)  # explicit tiny request honoured
    assert routes_to_oracle(1016, 64) and rank_routes_to_oracle(1016, 64)
    # the budgeted DEFAULT bp can degrade too: p = 8168 = 8*1021 is past
    # the full-lane budget but has no mid-size aligned divisor, so the
    # default policy resolves bp = 8 — a sliver sweep that must route
    # away just like an explicit sliver request would
    from repro.kernels.logistic_grad.ops import resolve_logistic_blocks
    assert resolve_logistic_blocks(128, 8168)[1] == 8
    assert routes_to_oracle(128, 8168)
    assert not routes_to_oracle(128, 8192)   # aligned divisors: on-kernel

    m, n, p = 2, 1016, 64
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(1), (m, n)))
    B = jax.random.normal(jax.random.PRNGKey(2), (m, p))
    out = logistic_grad(Xs, ys, B, interpret=True)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(logistic_grad_ref(Xs, ys, B)))
    S, c = rank_update(Xs, ys, interpret=True, use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys)
    np.testing.assert_array_equal(np.asarray(S), np.asarray(S_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


# ---------------------------------------------------------------------------
# rank_update (fused rank-n sufficient-statistics update)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,p,bp,bn", [(1, 64, 32, 16, 16),
                                         (3, 96, 48, 48, 32),
                                         (2, 128, 200, 128, 128),
                                         (4, 24, 16, 64, 64)])
@pytest.mark.parametrize("weighted", [False, True])
def test_rank_update_shapes_weights(m, n, p, bp, bn, weighted):
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jax.random.normal(jax.random.PRNGKey(1), (m, n))
    w = (jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.25
         ) if weighted else None
    S, c = rank_update(Xs, ys, w, block=(bp, bn), interpret=True,
                       use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys, w)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=1e-5)


def test_rank_update_bf16():
    m, n, p = 2, 64, 32
    Xs = jax.random.normal(KEY, (m, n, p), jnp.bfloat16)
    ys = jax.random.normal(jax.random.PRNGKey(1), (m, n), jnp.bfloat16)
    S, c = rank_update(Xs, ys, block=32, interpret=True, use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys)
    np.testing.assert_allclose(np.asarray(S, np.float32),
                               np.asarray(S_ref, np.float32), atol=0.05)
    np.testing.assert_allclose(np.asarray(c, np.float32),
                               np.asarray(c_ref, np.float32), atol=0.05)


def test_rank_update_unfused_matches_fused():
    m, n, p = 3, 48, 32
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jax.random.normal(jax.random.PRNGKey(1), (m, n))
    w = jax.random.uniform(jax.random.PRNGKey(2), (m, n)) + 0.25
    S_f, c_f = rank_update(Xs, ys, w, block=16, interpret=True,
                           use_kernel=True)
    S_u, c_u = rank_update_unfused(Xs, ys, w, block=16, interpret=True)
    np.testing.assert_allclose(np.asarray(S_f), np.asarray(S_u), atol=1e-6)
    np.testing.assert_allclose(np.asarray(c_f), np.asarray(c_u), atol=1e-6)


def test_rank_update_ragged_falls_back_to_oracle():
    m, n, p = 2, 33, 17
    Xs = jax.random.normal(KEY, (m, n, p))
    ys = jax.random.normal(jax.random.PRNGKey(1), (m, n))
    S, c = rank_update(Xs, ys, interpret=True, use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys)
    np.testing.assert_array_equal(np.asarray(S), np.asarray(S_ref))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c_ref))


def test_sufficient_stats_kernel_path_matches_default():
    """The engine entry point itself: kernel routing must be invisible
    to callers of `sufficient_stats`."""
    from repro.core.engine import sufficient_stats
    Xs = jax.random.normal(KEY, (3, 64, 48))
    ys = jax.random.normal(jax.random.PRNGKey(1), (3, 64))
    S0, c0 = sufficient_stats(Xs, ys)
    S1, c1 = sufficient_stats(Xs, ys, use_kernel=True, interpret=True,
                              block=32)
    np.testing.assert_allclose(np.asarray(S0), np.asarray(S1), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c0), np.asarray(c1), atol=1e-5)


# ---------------------------------------------------------------------------
# group_threshold
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("p,m", [(64, 4), (256, 10), (1024, 16), (200, 10)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_group_threshold_shapes_dtypes(p, m, dtype):
    B = jax.random.normal(KEY, (p, m), dtype) * 2.0
    out, keep = group_threshold(B, 2.0)
    ref_out, ref_keep = group_threshold_ref(B, 2.0)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(keep), np.asarray(ref_keep))


def test_group_threshold_edge_lambdas():
    B = jax.random.normal(KEY, (128, 8))
    out0, keep0 = group_threshold(B, 0.0)
    assert bool(jnp.all(keep0))                     # every row has norm > 0
    outinf, keepinf = group_threshold(B, 1e9)
    assert not bool(jnp.any(keepinf))
    assert bool(jnp.all(outinf == 0))


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s,n,k,h", [(128, 4, 4, 32), (256, 8, 2, 64),
                                     (64, 2, 1, 128), (192, 4, 2, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_kernel_shapes_dtypes(s, n, k, h, dtype):
    q = jax.random.normal(KEY, (2, s, n, h), dtype)
    kk = jax.random.normal(jax.random.PRNGKey(1), (2, s, k, h), dtype)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, s, k, h), dtype)
    out = flash_attention_op(q, kk, v, causal=True, bq=64, bk=64)
    ref = flash_attention_ref(q.astype(jnp.float32), kk.astype(jnp.float32),
                              v.astype(jnp.float32), causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_kernel_sliding_window(window):
    s = 256
    q = jax.random.normal(KEY, (1, s, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 4, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, s, 4, 32))
    out = flash_attention_op(q, k, v, causal=True, window=window,
                             bq=64, bk=64)
    ref = flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_kernel_noncausal():
    s = 128
    q = jax.random.normal(KEY, (1, s, 2, 64))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, s, 2, 64))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, s, 2, 64))
    out = flash_attention_op(q, k, v, causal=False, bq=32, bk=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)
