"""Serving correctness: prefill + decode must reproduce the full forward
pass exactly (f32), for every architecture family; sliding-window and
flash-attention paths must agree with the dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import (
    Batch, forward_decode, forward_prefill, forward_train, init_params,
)
from repro.serving.engine import greedy_generate

KEY = jax.random.PRNGKey(1)
B, S = 2, 24

FAMILIES = ["granite-3-2b", "minitron-4b", "recurrentgemma-9b",
            "mamba2-1.3b", "qwen3-moe-30b-a3b", "deepseek-moe-16b",
            "internvl2-2b", "seamless-m4t-medium"]


def _cfg(arch):
    cfg = smoke(get_config(arch)).replace(compute_dtype="float32",
                                          param_dtype="float32")
    if cfg.moe is not None:  # disable token dropping for exactness
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = 0.1 * jax.random.normal(KEY, (B, cfg.n_frontend_tokens,
                                           cfg.d_model))
    full, _ = forward_train(params, cfg, Batch(tokens=tokens, frontend=fe),
                            remat=False)
    off = cfg.n_frontend_tokens if cfg.arch_type == "vlm" else 0
    cl = S + 8 + off
    lp, caches = forward_prefill(params, cfg,
                                 Batch(tokens=tokens[:, :S], frontend=fe),
                                 cache_len=cl)
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-4)
    pos = jnp.asarray(S + off, jnp.int32)
    ld, _ = forward_decode(params, cfg, tokens[:, S:S + 1], pos, caches)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(full[:, S]), atol=2e-4)


def test_sliding_window_decode_ring_buffer():
    """Decode past the window: ring buffer must equal windowed attention."""
    cfg = _cfg("granite-3-2b").replace(window=16)
    params = init_params(KEY, cfg)
    T = 40  # > 2x window
    tokens = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab)
    full, _ = forward_train(params, cfg, Batch(tokens=tokens), remat=False)
    lp, caches = forward_prefill(params, cfg, Batch(tokens=tokens[:, :T]),
                                 cache_len=T + 8)
    ld, _ = forward_decode(params, cfg, tokens[:, T:T + 1],
                           jnp.asarray(T, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, T]),
                               atol=2e-4)


def test_multistep_decode_consistency():
    """5 decode steps == teacher-forced full forward at those positions."""
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    T = S + 5
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    full, _ = forward_train(params, cfg, Batch(tokens=tokens), remat=False)
    _, caches = forward_prefill(params, cfg, Batch(tokens=tokens[:, :S]),
                                cache_len=T + 4)
    for i in range(5):
        pos = jnp.asarray(S + i, jnp.int32)
        ld, caches = forward_decode(params, cfg, tokens[:, S + i:S + i + 1],
                                    pos, caches)
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, S + i]), atol=3e-4)


def test_greedy_generate_shapes_and_determinism():
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    out1 = greedy_generate(params, cfg, prompt, steps=6)
    out2 = greedy_generate(params, cfg, prompt, steps=6)
    assert out1.shape == (B, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :8]), np.asarray(prompt))
