"""Serving correctness: prefill + decode must reproduce the full forward
pass exactly (f32), for every architecture family; sliding-window and
flash-attention paths must agree with the dense reference."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.models import (
    Batch, forward_decode, forward_prefill, forward_train, init_params,
)
from repro.serving.engine import greedy_generate

KEY = jax.random.PRNGKey(1)
B, S = 2, 24

FAMILIES = ["granite-3-2b", "minitron-4b", "recurrentgemma-9b",
            "mamba2-1.3b", "qwen3-moe-30b-a3b", "deepseek-moe-16b",
            "internvl2-2b", "seamless-m4t-medium"]


def _cfg(arch):
    cfg = smoke(get_config(arch)).replace(compute_dtype="float32",
                                          param_dtype="float32")
    if cfg.moe is not None:  # disable token dropping for exactness
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", FAMILIES)
def test_prefill_decode_matches_forward(arch):
    cfg = _cfg(arch)
    params = init_params(KEY, cfg)
    tokens = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = 0.1 * jax.random.normal(KEY, (B, cfg.n_frontend_tokens,
                                           cfg.d_model))
    full, _ = forward_train(params, cfg, Batch(tokens=tokens, frontend=fe),
                            remat=False)
    off = cfg.n_frontend_tokens if cfg.arch_type == "vlm" else 0
    cl = S + 8 + off
    lp, caches = forward_prefill(params, cfg,
                                 Batch(tokens=tokens[:, :S], frontend=fe),
                                 cache_len=cl)
    np.testing.assert_allclose(np.asarray(lp[:, 0]),
                               np.asarray(full[:, S - 1]), atol=2e-4)
    pos = jnp.asarray(S + off, jnp.int32)
    ld, _ = forward_decode(params, cfg, tokens[:, S:S + 1], pos, caches)
    np.testing.assert_allclose(np.asarray(ld[:, 0]),
                               np.asarray(full[:, S]), atol=2e-4)


def test_sliding_window_decode_ring_buffer():
    """Decode past the window: ring buffer must equal windowed attention."""
    cfg = _cfg("granite-3-2b").replace(window=16)
    params = init_params(KEY, cfg)
    T = 40  # > 2x window
    tokens = jax.random.randint(KEY, (B, T + 1), 0, cfg.vocab)
    full, _ = forward_train(params, cfg, Batch(tokens=tokens), remat=False)
    lp, caches = forward_prefill(params, cfg, Batch(tokens=tokens[:, :T]),
                                 cache_len=T + 8)
    ld, _ = forward_decode(params, cfg, tokens[:, T:T + 1],
                           jnp.asarray(T, jnp.int32), caches)
    np.testing.assert_allclose(np.asarray(ld[:, 0]), np.asarray(full[:, T]),
                               atol=2e-4)


def test_multistep_decode_consistency():
    """5 decode steps == teacher-forced full forward at those positions."""
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    T = S + 5
    tokens = jax.random.randint(KEY, (B, T), 0, cfg.vocab)
    full, _ = forward_train(params, cfg, Batch(tokens=tokens), remat=False)
    _, caches = forward_prefill(params, cfg, Batch(tokens=tokens[:, :S]),
                                cache_len=T + 4)
    for i in range(5):
        pos = jnp.asarray(S + i, jnp.int32)
        ld, caches = forward_decode(params, cfg, tokens[:, S + i:S + i + 1],
                                    pos, caches)
        np.testing.assert_allclose(np.asarray(ld[:, 0]),
                                   np.asarray(full[:, S + i]), atol=3e-4)


def test_greedy_generate_shapes_and_determinism():
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    out1 = greedy_generate(params, cfg, prompt, steps=6)
    out2 = greedy_generate(params, cfg, prompt, steps=6)
    assert out1.shape == (B, 14)
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    np.testing.assert_array_equal(np.asarray(out1[:, :8]), np.asarray(prompt))


def test_greedy_generate_zero_steps_is_identity():
    """steps=0 must return the prompt unchanged — no decode, no junk
    column from the prefill's argmax."""
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    out = greedy_generate(params, cfg, prompt, steps=0)
    assert out.shape == (B, 8)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(prompt))


@pytest.mark.parametrize("extra", [0, 3, 16])
def test_greedy_generate_cache_extra_invariance(extra):
    """`cache_extra` only pads the cache past the written range, so it
    must never change the decoded tokens (the scan writes through
    position S + steps - 2 and the slack stays untouched)."""
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, 8), 0, cfg.vocab)
    base = greedy_generate(params, cfg, prompt, steps=5)
    out = greedy_generate(params, cfg, prompt, steps=5, cache_extra=extra)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_greedy_generate_matches_manual_decode_loop():
    """The scan must equal an unrolled prefill + per-token decode loop
    token for token — in particular the LAST token must be a real
    decoded token, not an artifact of the scan length (the old code ran
    one extra decode step and always sliced its result away)."""
    cfg = _cfg("granite-3-2b")
    params = init_params(KEY, cfg)
    S0, steps = 8, 5
    prompt = jax.random.randint(KEY, (B, S0), 0, cfg.vocab)

    logits, caches = forward_prefill(params, cfg, Batch(tokens=prompt),
                                     cache_len=S0 + steps)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    toks = [tok]
    for i in range(steps - 1):
        ld, caches = forward_decode(params, cfg, tok[:, None],
                                    jnp.asarray(S0 + i, jnp.int32), caches)
        tok = jnp.argmax(ld[:, -1], axis=-1).astype(jnp.int32)
        toks.append(tok)
    manual = np.stack([np.asarray(t) for t in toks], axis=1)

    out = greedy_generate(params, cfg, prompt, steps=steps)
    np.testing.assert_array_equal(np.asarray(out[:, S0:]), manual)
