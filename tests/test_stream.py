"""Streaming DSML subsystem tests.

Contract: sufficient statistics are additive, so (a) ingesting a
dataset in ANY chunking and refitting reproduces `dsml_fit` on the
concatenated data; (b) a warm-started refit on unchanged statistics is
a fixed point; (c) the sharded data x task accumulator equals the host
path; (d) decay and window variants match their closed forms; (e) the
service drives ingest/refit/predict/save/load coherently.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dsml_fit, gen_regression, sufficient_stats
from repro.stream import (
    StreamingDsmlService, ingest, init_stream_state, init_window, merge,
    refit, window_ingest, window_stats,
)
from repro.substrate import run_probe

LAM, MU, THR = 0.4, 0.2, 1.0
ITERS = dict(lasso_iters=200, debias_iters=200)


def _data(m=4, n=120, p=48, s=5, seed=0):
    return gen_regression(jax.random.PRNGKey(seed), m=m, n=n, p=p, s=s)


def _chunks(data, k):
    return zip(jnp.split(data.Xs, k, axis=1), jnp.split(data.ys, k, axis=1))


def _ingest_all(data, k, **kw):
    state = init_stream_state(data.Xs.shape[0], data.Xs.shape[2])
    for Xc, yc in _chunks(data, k):
        state = ingest(state, Xc, yc, **kw)
    return state


# ---------------------------------------------------------------------------
# additivity: chunked ingest == one-shot statistics == dsml_fit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 3, 8])
def test_chunked_ingest_matches_one_shot_stats(k):
    data = _data()
    state = _ingest_all(data, k)
    S, c = sufficient_stats(data.Xs, data.ys)
    np.testing.assert_allclose(np.asarray(state.Sigmas), np.asarray(S),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.cs), np.asarray(c),
                               atol=1e-5)
    assert float(state.counts[0]) == data.Xs.shape[1]


@pytest.mark.parametrize("k", [1, 3, 8])
def test_stream_refit_reproduces_dsml_fit(k):
    """The acceptance bar: ingest in k chunks, refit once, and get the
    batch `dsml_fit` answer on the concatenated data to <= 1e-5."""
    data = _data()
    state, info = refit(_ingest_all(data, k), LAM, MU, THR, **ITERS)
    ref = dsml_fit(data.Xs, data.ys, LAM, MU, THR, **ITERS)
    np.testing.assert_allclose(np.asarray(state.beta_tilde),
                               np.asarray(ref.beta_tilde), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.beta_u),
                               np.asarray(ref.beta_u), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(state.support),
                                  np.asarray(ref.support))
    assert int(info.generation) == 1


def test_warm_refit_on_unchanged_stats_is_fixed_point():
    data = _data()
    state, _ = refit(_ingest_all(data, 3), LAM, MU, THR, **ITERS)
    again, info = refit(state, LAM, MU, THR, **ITERS)
    np.testing.assert_allclose(np.asarray(again.beta_tilde),
                               np.asarray(state.beta_tilde), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(again.support),
                                  np.asarray(state.support))
    assert float(info.jaccard) == 1.0
    assert int(again.generation) == 2


def test_merge_matches_single_stream():
    data = _data()
    Xa, Xb = jnp.split(data.Xs, 2, axis=1)
    ya, yb = jnp.split(data.ys, 2, axis=1)
    m, p = data.Xs.shape[0], data.Xs.shape[2]
    a = ingest(init_stream_state(m, p), Xa, ya)
    b = ingest(init_stream_state(m, p), Xb, yb)
    both = merge(a, b)
    S, c = sufficient_stats(data.Xs, data.ys)
    np.testing.assert_allclose(np.asarray(both.Sigmas), np.asarray(S),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(both.cs), np.asarray(c), atol=1e-5)


# ---------------------------------------------------------------------------
# non-stationary variants
# ---------------------------------------------------------------------------

def test_decayed_ingest_matches_closed_form():
    """With per-chunk decay d, the state must equal the weighted average
    sum_k d^{K-k} n_k stats_k / sum_k d^{K-k} n_k."""
    data = _data()
    d, k = 0.5, 4
    state = _ingest_all(data, k, decay=d)
    chunks = list(_chunks(data, k))
    w = jnp.asarray([d ** (k - 1 - i) for i in range(k)])
    num_S, num_c, den = 0.0, 0.0, 0.0
    for wi, (Xc, yc) in zip(w, chunks):
        S, c = sufficient_stats(Xc, yc)
        n = Xc.shape[1]
        num_S, num_c, den = num_S + wi * n * S, num_c + wi * n * c, den + wi * n
    np.testing.assert_allclose(np.asarray(state.Sigmas),
                               np.asarray(num_S / den), atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.cs),
                               np.asarray(num_c / den), atol=1e-5)
    np.testing.assert_allclose(float(state.counts[0]), float(den), rtol=1e-6)


def test_weighted_ingest_matches_manual_weighting():
    data = _data(m=2, n=40, p=16, s=3)
    w = jax.random.uniform(jax.random.PRNGKey(3), data.ys.shape,
                           minval=0.2, maxval=1.0)
    state = ingest(init_stream_state(2, 16), data.Xs, data.ys, weights=w)
    Xw = data.Xs * w[..., None]
    S = jnp.einsum("tni,tnj->tij", Xw, data.Xs) / jnp.sum(w, 1)[:, None, None]
    np.testing.assert_allclose(np.asarray(state.Sigmas), np.asarray(S),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.counts),
                               np.asarray(jnp.sum(w, 1)), rtol=1e-5)


def test_window_stats_cover_exactly_last_w_chunks():
    data = _data()
    k, w = 6, 3
    win = init_window(w, data.Xs.shape[0], data.Xs.shape[2])
    chunks = list(_chunks(data, k))
    for Xc, yc in chunks:
        win = window_ingest(win, Xc, yc)
    X_tail = jnp.concatenate([Xc for Xc, _ in chunks[-w:]], axis=1)
    y_tail = jnp.concatenate([yc for _, yc in chunks[-w:]], axis=1)
    S, c, counts = window_stats(win)
    S_ref, c_ref = sufficient_stats(X_tail, y_tail)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=1e-5)
    assert float(counts[0]) == X_tail.shape[1]
    assert int(win.seen) == k


# ---------------------------------------------------------------------------
# sharded accumulation (engine-level SPMD)
# ---------------------------------------------------------------------------

def test_sharded_ingest_matches_host_single_device():
    from repro.stream import ingest_sharded
    from repro.substrate import data_task_mesh
    mesh = data_task_mesh(n_task=1, n_data=1)
    data = _data()
    host = _ingest_all(data, 2)
    shard = init_stream_state(data.Xs.shape[0], data.Xs.shape[2])
    for Xc, yc in _chunks(data, 2):
        shard = ingest_sharded(shard, Xc, yc, mesh)
    np.testing.assert_allclose(np.asarray(host.Sigmas),
                               np.asarray(shard.Sigmas), atol=1e-5)
    np.testing.assert_allclose(np.asarray(host.cs), np.asarray(shard.cs),
                               atol=1e-5)


_MESH8 = r"""
import jax, numpy as np
import jax.numpy as jnp
from repro.core import dsml_fit, gen_regression
from repro.stream import ingest_sharded, init_stream_state, refit
from repro.substrate import data_task_mesh

mesh = data_task_mesh(n_task=2)            # 8 devices -> (4 data, 2 task)
data = gen_regression(jax.random.PRNGKey(1), m=4, n=160, p=48, s=5)
state = init_stream_state(4, 48)
for Xc, yc in zip(jnp.split(data.Xs, 4, axis=1), jnp.split(data.ys, 4, axis=1)):
    state = ingest_sharded(state, Xc, yc, mesh)
state, _ = refit(state, 0.4, 0.2, 1.0, lasso_iters=200, debias_iters=200)
ref = dsml_fit(data.Xs, data.ys, 0.4, 0.2, 1.0, lasso_iters=200,
               debias_iters=200)
err = float(np.max(np.abs(np.asarray(state.beta_tilde) -
                          np.asarray(ref.beta_tilde))))
sup_eq = bool(np.all(np.asarray(state.support) == np.asarray(ref.support)))
print(f"RESULT err={err} sup_eq={sup_eq}")
"""


def test_sharded_ingest_refit_matches_dsml_eight_devices():
    """Chunked SPMD ingest over a (4 data x 2 task) mesh, then refit,
    must reproduce `dsml_fit` on the concatenated data to <= 1e-5."""
    res = run_probe(_MESH8, n_devices=8, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    m = re.search(r"RESULT err=([\d.e+-]+) sup_eq=(\w+)", res.stdout)
    assert m, res.stdout
    assert float(m.group(1)) < 1e-5
    assert m.group(2) == "True"


# ---------------------------------------------------------------------------
# service driver
# ---------------------------------------------------------------------------

def test_service_ingest_refit_predict_roundtrip(tmp_path):
    data = _data()
    svc = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR,
                               refit_every=60, lasso_iters=200,
                               debias_iters=200)
    infos = [svc.ingest(Xc, yc) for Xc, yc in _chunks(data, 4)]
    assert svc.generation >= 1                     # cadence fired
    assert any(i is not None for i in infos)
    assert svc.samples_seen == data.Xs.shape[1]
    pred = svc.predict(data.Xs)
    assert pred.shape == data.ys.shape
    assert bool(jnp.all(jnp.isfinite(pred)))
    shared = svc.predict(data.Xs[0])               # shared-design scoring
    assert shared.shape == (4, data.Xs.shape[1])

    path = str(tmp_path / "stream_state")
    svc.save(path)
    fresh = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR)
    fresh.load(path)
    assert fresh.generation == svc.generation
    np.testing.assert_array_equal(np.asarray(fresh.predict(data.Xs)),
                                  np.asarray(pred))


def test_service_window_mode_survives_save_load():
    """A restored window-mode service must keep serving the same model:
    the ring buffer round-trips with the state, and a refit right after
    restore must NOT wipe the statistics."""
    data = _data()
    svc = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR, window=3,
                               refit_every=60, lasso_iters=200,
                               debias_iters=200)
    for Xc, yc in _chunks(data, 4):
        svc.ingest(Xc, yc)
    assert svc.generation >= 1
    before = np.asarray(svc.state.beta_tilde)
    assert np.abs(before).max() > 0

    import tempfile, os
    path = os.path.join(tempfile.mkdtemp(), "win_state")
    svc.save(path)
    fresh = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR, window=3,
                                 refit_every=60, lasso_iters=200,
                                 debias_iters=200)
    fresh.load(path)
    assert int(fresh.window.seen) == int(svc.window.seen)
    fresh.refit()
    assert np.abs(np.asarray(fresh.state.beta_tilde)).max() > 0
    assert float(jnp.max(jnp.abs(fresh.state.Sigmas))) > 0

    # a refit on a NEVER-fed window service must not zero the stats
    empty = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR, window=3)
    empty.state = svc.state
    empty.refit()
    assert float(jnp.max(jnp.abs(empty.state.Sigmas))) > 0


def test_service_rejects_decay_with_window():
    with pytest.raises(ValueError):
        StreamingDsmlService(2, 8, lam=LAM, mu=MU, Lam=THR,
                             window=2, decay=0.9)


def test_service_rejects_window_ckpt_in_plain_service(tmp_path):
    """A window-mode checkpoint must not silently load as cumulative."""
    svc = StreamingDsmlService(2, 8, lam=LAM, mu=MU, Lam=THR, window=2)
    path = str(tmp_path / "win_ckpt")
    svc.save(path)
    plain = StreamingDsmlService(2, 8, lam=LAM, mu=MU, Lam=THR)
    with pytest.raises(ValueError):
        plain.load(path)


def test_service_widens_refit_interval_when_support_stable():
    data = _data(n=240)
    svc = StreamingDsmlService(4, 48, lam=LAM, mu=MU, Lam=THR,
                               refit_every=40, drift_threshold=0.05,
                               lasso_iters=200, debias_iters=200,
                               warm_lasso_iters=200)
    for Xc, yc in _chunks(data, 6):
        svc.ingest(Xc, yc)
    # identical-distribution traffic: once warm, supports stop moving and
    # the adaptive cadence must have backed off from the base interval.
    assert svc.generation >= 2
    assert svc._interval > svc.refit_every
    assert float(svc.last_info.jaccard) == 1.0
