"""Executable fixture: the PR-9 `ServingFront` lifecycle, pre-fix.

`PreFixServingFront` overrides start/stop/_run with their original
PR-9 bodies, preserving both real bugs this PR fixed:

* `stop()` clears `self._worker`, reads/clears `_carry`, drains the
  queue, and fails futures UNCONDITIONALLY after `join(timeout)` —
  even when the join timed out and the worker is still alive and
  resolving those same futures;
* `start()` reuses one shared `threading.Event` via `clear()`, so
  restarting after a timed-out stop un-stops the zombie worker (and
  spawns a second worker racing it on the same queue).

`tests/test_interleave.py` replays the race deterministically on this
class and proves the fixed parent coherent under the same schedule;
`tests/test_invariants.py` pins that the static checker (RL4xx) flags
this file's stop() as the violation it is.
"""
import queue
import threading
from typing import List, Optional

from repro.stream.serve import ServingFront, _Request


class PreFixServingFront(ServingFront):

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_worker": "atomic-publish:start,stop",
        "_stop": "atomic-publish:start",
        "_carry": "worker-only:_run",
    }

    def start(self) -> "PreFixServingFront":
        if self._worker is not None and self._worker.is_alive():
            return self
        self._stop.clear()
        self._worker = threading.Thread(
            target=self._run, name="repro-serving-front", daemon=True)
        self._worker.start()
        return self

    def stop(self, timeout: float = 5.0) -> None:
        if self._worker is None:
            return
        self._stop.set()
        self._q.put(None)
        self._worker.join(timeout)
        self._worker = None
        leftovers: List[Optional[_Request]] = []
        if self._carry is not None:
            leftovers.append(self._carry)
            self._carry = None
        while True:
            try:
                leftovers.append(self._q.get_nowait())
            except queue.Empty:
                break
        for req in leftovers:
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("serving front stopped"))

    def _run(self, stop: Optional[threading.Event] = None) -> None:
        while not self._stop.is_set():
            batch = self._drain()
            if not batch:
                continue
            self._process_safe(batch)
