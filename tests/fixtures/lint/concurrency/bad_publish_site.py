"""Fixture: RL402 on atomic-publish site discipline.

`_model` may only be published from `publish` and `bump` (plus
`__init__`). Two findings: an assignment from outside the closed site
set, and a read-modify-write at an allowed site (the read and the
publish are two steps — a racing reader can interleave between them).
The clean publish in `publish()` must NOT fire.
"""
import threading


class Publisher:
    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_model": "atomic-publish:publish,bump",
    }

    def __init__(self):
        self._model = 0
        self._stopped = threading.Event()

    def publish(self, snapshot):
        self._model = snapshot                  # clean: allowed site

    def bump(self):
        self._model = self._model + 1           # RL402: RMW at a site

    def sneak(self, snapshot):
        self._model = snapshot                  # RL402: not a site

    def read(self):
        return self._model                      # clean: reads are free
