"""Fixture: RL401 — shared state without a declared sync policy.

Three violation shapes, one finding each:
* a thread-spawning class with no `_SYNC_POLICY` at all;
* a declared class assigning an attribute its policy map does not
  cover (and no `"*"` default);
* a policy string the grammar does not recognize.
"""
import threading


class SpawnsWithoutPolicy:                      # RL401: no declaration
    def __init__(self):
        self._result = None

    def start(self):
        threading.Thread(target=self._work).start()

    def _work(self):
        self._result = 42


class UncoveredAttribute:
    _SYNC_POLICY = {
        "_a": "immutable-after-init",
    }

    def __init__(self):
        self._a = 1
        self._b = 2                             # RL401: not covered, no "*"


class MalformedPolicy:
    _SYNC_POLICY = {
        "_x": "quantum-entangled",              # RL401: unknown grammar
    }

    def __init__(self):
        self._x = 0
