"""Fixture: RL402 on in-place mutation and policy-breaking writes.

Four findings: compound (`+=`) and subscript mutation of an
atomic-publish attribute (in-place edits are visible to readers
mid-edit — atomic publication means building a NEW value and swapping
the reference), a post-init write to an immutable-after-init
attribute, and an unlocked touch of a lock-disciplined attribute. The
locked access in `record` must NOT fire.
"""
import threading


class Mutator:
    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_snap": "atomic-publish:publish",
        "_counts": "lock:_lock",
    }

    def __init__(self):
        self._snap = {}
        self._counts = {}
        self._lock = threading.Lock()
        self.cfg = "fixed"

    def publish(self, snapshot):
        self._snap = snapshot                   # clean: allowed site

    def patch(self, key, value):
        self._snap[key] = value                 # RL402: subscript mutation

    def grow(self, delta):
        self._snap += delta                     # RL402: compound mutation

    def reconfigure(self, cfg):
        self.cfg = cfg                          # RL402: immutable write

    def record(self, key):
        with self._lock:
            self._counts[key] = 1               # clean: lock held

    def peek(self, key):
        return self._counts.get(key, 0)         # RL402: lock not held
