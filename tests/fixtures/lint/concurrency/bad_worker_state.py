"""Fixture: RL403 — worker-only state touched from a public method.

A distilled copy of the pre-fix `ServingFront.stop()` bug: `_carry` is
owned by the worker's drain loop, but `stop()` reads it and clears it
while the worker may still be running. Two findings (the read in the
condition, the clearing write). The worker-side touches in `_run` and
its callee `_drain` must NOT fire — they sit inside the declared
entry's call graph.
"""
import queue
import threading


class Front:
    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_worker": "atomic-publish:start,stop",
        "_carry": "worker-only:_run",
    }

    def __init__(self):
        self._q = queue.Queue()
        self._carry = None
        self._worker = None

    def start(self):
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    def stop(self):
        if self._carry is not None:             # RL403: racing read
            self._carry = None                  # RL403: racing write

    def _drain(self):
        if self._carry is not None:             # clean: in worker graph
            item, self._carry = self._carry, None
            return item
        return self._q.get(timeout=0.1)

    def _run(self):
        while True:
            item = self._drain()
            if item is None:
                return
