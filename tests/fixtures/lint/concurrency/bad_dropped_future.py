"""Fixture: RL405 — futures with an exit path that strands a waiter.

Two findings: a future that is never resolved, returned, or handed
off at all, and a validation `raise` sitting between a future's
creation and its first handoff. `clean` validates BEFORE minting the
future (the serving-front `submit` pattern) and must NOT fire.
"""
from concurrent.futures import Future


def lost(compute):
    fut = Future()                              # RL405: never handed off
    compute()


def raises_between(q, x):
    fut = Future()
    if x < 0:
        raise ValueError("bad request")         # RL405: fut stranded
    q.put((x, fut))
    return fut


def clean(q, x):
    if x < 0:
        raise ValueError("bad request")
    fut = Future()
    q.put((x, fut))
    return fut
