"""Fixture: RL404 — blocking calls while a declared lock is held.

Four findings under `with self._lock`: an engine solve (`refit`), a
timeout-less `Future.result()`, a timeout-less `Queue.get()`, and a
timeout-less `join()` — each parks the lock holder on another thread's
progress, so every contender stalls with it. The timeout-bounded
variants in `bounded` must NOT fire.
"""
import queue
import threading


class LockedDriver:
    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_state": "lock:_lock",
    }

    def __init__(self):
        self._lock = threading.Lock()
        self._state = {}
        self._q = queue.Queue()

    def refit(self):
        return {}

    def refresh(self, fut, worker):
        with self._lock:
            self._state = self.refit()          # RL404: solve under lock
            value = fut.result()                # RL404: unbounded wait
            item = self._q.get()                # RL404: unbounded get
            worker.join()                       # RL404: unbounded join
            return value, item

    def bounded(self, fut, worker):
        with self._lock:
            self._state = {}
            value = fut.result(1.0)             # clean: bounded
            item = self._q.get(timeout=1.0)     # clean: bounded
            worker.join(1.0)                    # clean: bounded
            return value, item
