"""Known-bad fixture: RL106 — mutating global jax config outside the
allowlist. Library code must not flip process-global precision or x64
state under the caller's feet."""
import jax


def enable_x64():
    jax.config.update("jax_enable_x64", True)  # RL106
