"""Known-bad fixture: RL107 — Python control flow / scalarization on
traced values inside a jit-reachable function."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x):
    g = jnp.sum(x)
    if g > 0:          # RL107: Python `if` on a traced value
        x = x - 1.0
    return float(g)    # RL107: float() on a traced value
