"""RL109 fixture: broad exception handlers that swallow silently.

Two violations; the compliant handlers below must NOT be flagged.
"""
import traceback

from repro import obs


def swallow_with_pass(path):
    try:
        return open(path).read()
    except Exception:           # RL109: silent pass
        pass


def swallow_with_return(compute):
    try:
        return compute()
    except:                     # RL109: bare except, silent fallback
        return None


def ok_reraise(path):
    try:
        return open(path).read()
    except Exception as e:
        raise RuntimeError(f"cannot read {path}") from e


def ok_records_counter(compute):
    try:
        return compute()
    except Exception:
        obs.inc("fixture.degraded")
        return None


def ok_captures_traceback(compute):
    try:
        return compute()
    except Exception:
        traceback.print_exc()
        return None


def ok_narrowed(path):
    try:
        return open(path).read()
    except (OSError, UnicodeDecodeError):
        return None
