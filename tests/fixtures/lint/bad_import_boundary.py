"""Known-bad fixture: RL101 — sharding plumbing outside substrate/.

This file is NOT importable production code; it exists so
tests/test_invariants.py can assert the linter fires on each
violation class. Kept syntactically valid but never executed.
"""
from jax.experimental.shard_map import shard_map  # RL101
import jax


def build(mesh, f):
    mesh = jax.make_mesh((2,), ("tasks",))        # RL101
    jax.lax.psum(1.0, "tasks")                    # RL101
    return shard_map(f, mesh=mesh)
