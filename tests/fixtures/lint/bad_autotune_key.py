"""Known-bad fixture: RL105 — un-namespaced autotune cache writes.

The pre-PR-4 regression class: a bare `"tpu_m8_..."` key collides
across kernels once two sweeps share `.cache/autotune.json`.
"""
_memory_cache: dict = {}
disk: dict = {}


def remember(backend: str, best):
    _memory_cache[f"{backend}_m8_p128_float32"] = best   # RL105: no '<kernel>/'
    disk["tpu_m8_p128_float32"] = best                   # RL105


def remember_good(best):
    # namespaced writes are fine — must NOT fire
    _memory_cache["fista_step/tpu_m8_p128_r4_float32"] = best
