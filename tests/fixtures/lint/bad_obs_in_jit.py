"""Known-bad fixture: RL108 — repro.obs telemetry calls inside
jit-reachable code (they'd record per-compilation, not per-call)."""
import jax

from repro import obs


def _accumulate(x):
    with obs.span("fixture.step"):   # RL108: reachable from jit root
        return x + 1.0


@jax.jit
def fused_step(x):
    obs.inc("fixture.calls")         # RL108: directly inside a jit root
    return _accumulate(x)


def report(x):
    obs.inc("fixture.reports")       # eager, never jit-reached: MUST NOT fire
    return x
