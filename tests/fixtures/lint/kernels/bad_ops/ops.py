"""Known-bad fixture: RL102/RL103/RL104 — a `kernels/*/ops.py` that
imports pallas directly (dispatchers must not), and whose public entry
reaches the pallas path without `validate_block` or a routing
predicate."""
import jax.experimental.pallas as pl  # RL102: pallas import outside kernel.py
import jax.numpy as jnp


def _bad_pallas(x, bn):
    # stand-in for a kernel launch; the name suffix is what the
    # dispatcher-convention check keys on
    return pl.pallas_call(lambda ref, o: None, grid=(1,))(x)


def bad_op(x, block=128):
    # RL103: never calls common.validate_block
    # RL104: never consults a routes_to_oracle / is_ragged predicate
    return _bad_pallas(jnp.asarray(x), block)
