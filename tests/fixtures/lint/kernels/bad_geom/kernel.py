"""Known-bad fixture: RL201/RL202 — BlockSpec geometry that disagrees
with its own grid, and a tile parameter with no divisibility guard."""
import jax.experimental.pallas as pl


def _body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def bad_geom_pallas(x, n, p, bn, bp):
    # no `assert n % bn == 0` anywhere in this module -> RL202 on bn/bp
    x_spec = pl.BlockSpec((bn, bp), lambda i: (i, 0))  # RL201: arity 1 vs grid 2
    o_spec = pl.BlockSpec((bn, bp), lambda i, j: (i, j))
    return pl.pallas_call(
        _body,
        grid=(n // bn, p // bp),
        in_specs=[x_spec],
        out_specs=o_spec,
    )(x)
