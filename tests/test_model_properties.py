"""Model-level property tests: SSD chunk invariance, hybrid pattern
structure, RG-LRU scan vs sequential reference, MoE invariants, dirty
model baseline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, smoke
from repro.core.dirty import dirty_model
from repro.core import gen_regression, hamming, support_of
from repro.models import init_params
from repro.models.config import SsdConfig
from repro.models.rglru import (
    _rglru_gates, init_recurrent_params, rglru_scan,
)
from repro.models.ssd import init_ssd_params, ssd_block_train
from repro.models.moe import init_moe_params, moe_apply

KEY = jax.random.PRNGKey(0)


def test_ssd_chunk_size_invariance():
    """The chunked SSD algorithm must give identical output for any chunk."""
    cfg = smoke(get_config("mamba2-1.3b")).replace(
        compute_dtype="float32", param_dtype="float32")
    p = init_ssd_params(KEY, cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    outs = []
    for chunk in (8, 16, 32, 64):
        c2 = cfg.replace(ssd=dataclasses.replace(cfg.ssd, chunk=chunk))
        outs.append(np.asarray(ssd_block_train(p, x, c2)))
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-4)


def test_ssd_is_causal():
    """Perturbing future inputs must not change past outputs."""
    cfg = smoke(get_config("mamba2-1.3b")).replace(
        compute_dtype="float32", param_dtype="float32")
    p = init_ssd_params(KEY, cfg, jnp.float32)
    x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (1, 48, cfg.d_model))
    y1 = ssd_block_train(p, x, cfg)
    x2 = x.at[:, 30:].set(5.0)
    y2 = ssd_block_train(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :30]),
                               np.asarray(y2[:, :30]), atol=1e-5)


def test_rglru_scan_matches_sequential():
    cfg = smoke(get_config("recurrentgemma-9b")).replace(
        compute_dtype="float32", param_dtype="float32")
    p = init_recurrent_params(KEY, cfg, jnp.float32)
    u = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (2, 33, 256))
    h_scan = rglru_scan(p, u, cfg.rglru.c)
    a, b = _rglru_gates(p, u, cfg.rglru.c)
    h = jnp.zeros((2, 256))
    hs = []
    for t in range(33):
        h = a[:, t] * h + b[:, t]
        hs.append(h)
    h_seq = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(h_scan), np.asarray(h_seq),
                               atol=1e-5)


def test_rglru_state_is_contractive():
    """|a_t| < 1 for all inputs: the recurrence cannot blow up."""
    cfg = smoke(get_config("recurrentgemma-9b"))
    p = init_recurrent_params(KEY, cfg, jnp.float32)
    u = 100.0 * jax.random.normal(KEY, (1, 16, 256))
    a, b = _rglru_gates(p, u, cfg.rglru.c)
    # a = exp(-c*softplus(lam)*r) < 1 mathematically; r ~ 0 can round a to
    # exactly 1.0 in f32, so assert non-expansive + strictly contractive
    # on average
    assert float(jnp.max(a)) <= 1.0
    assert float(jnp.mean(a)) < 1.0
    assert float(jnp.min(a)) > 0.0


def test_hybrid_pattern_structure():
    cfg = get_config("recurrentgemma-9b")
    kinds = cfg.layer_kinds()
    assert len(kinds) == 38
    assert kinds[:3] == ("recurrent", "recurrent", "local_attn")
    # 1 attention per 2 recurrent
    assert kinds.count("local_attn") == 12
    assert kinds.count("recurrent") == 26


def test_moe_every_token_routed_or_dropped_consistently():
    cfg = smoke(get_config("qwen3-moe-30b-a3b")).replace(
        compute_dtype="float32", param_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux["moe_drop_frac"]) == 0.0          # high capacity: no drops
    assert float(aux["moe_aux_loss"]) > 0.0
    # with tiny capacity, drops must be reported
    cfg2 = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=0.1))
    _, aux2 = moe_apply(p, x, cfg2)
    assert float(aux2["moe_drop_frac"]) > 0.0


def test_moe_permutation_equivariance():
    """Permuting tokens permutes outputs (routing is per-token)."""
    cfg = smoke(get_config("qwen3-moe-30b-a3b")).replace(
        compute_dtype="float32", param_dtype="float32")
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe_params(KEY, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 12, cfg.d_model))
    out, _ = moe_apply(p, x, cfg)
    perm = jax.random.permutation(jax.random.PRNGKey(5), 12)
    out_p, _ = moe_apply(p, x[:, perm], cfg)
    np.testing.assert_allclose(np.asarray(out[:, perm]), np.asarray(out_p),
                               atol=1e-4)


def test_dirty_model_separates_shared_and_private():
    """Shared support + a few private coefficients: S catches the shared
    rows; the combined estimate recovers the union support."""
    data = gen_regression(jax.random.PRNGKey(7), m=6, n=120, p=80, s=5,
                          signal_low=0.5)
    B, S, E = dirty_model(data.Xs, data.ys, lam_s=0.4, lam_e=0.2, iters=600)
    assert B.shape == (80, 6)
    h = int(hamming(support_of(B, 1e-2), data.support))
    assert h <= 3
