"""Substrate-layer tests: optimizer, schedules, checkpointing, data
pipeline, HLO analysis, sharding rules, config registry."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint.io import restore_pytree, save_pytree
from repro.configs import ASSIGNED, get_config, smoke
from repro.data.synth_tokens import synthetic_lm_batches
from repro.launch.hlo import analyze_hlo, roofline
from repro.optim.adamw import (
    AdamWState, adamw_init, adamw_update, global_norm, warmup_cosine,
)
from repro.sharding.rules import fit_spec, fit_first

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _toy_params():
    return {"w": jnp.ones((4, 4), jnp.bfloat16),
            "b": jnp.zeros((4,), jnp.float32)}


def test_adamw_moves_toward_gradient():
    params = _toy_params()
    state = adamw_init(params)
    grads = {"w": jnp.ones((4, 4)), "b": jnp.ones((4,))}
    new_params, state, metrics = adamw_update(grads, state, params, lr=0.1,
                                              weight_decay=0.0)
    assert float(new_params["w"].astype(jnp.float32).mean()) < 1.0
    assert float(metrics["grad_norm"]) > 0


def test_adamw_clipping_bounds_update():
    params = _toy_params()
    state = adamw_init(params)
    huge = {"w": jnp.full((4, 4), 1e6), "b": jnp.full((4,), 1e6)}
    small = {"w": jnp.full((4, 4), 1e-3), "b": jnp.full((4,), 1e-3)}
    p1, _, m1 = adamw_update(huge, state, params, lr=0.1, clip_norm=1.0,
                             weight_decay=0.0)
    p2, _, m2 = adamw_update(small, adamw_init(params), params, lr=0.1,
                             clip_norm=1.0, weight_decay=0.0)
    # after normalization both give the same m/sqrt(v) direction -> same step
    np.testing.assert_allclose(np.asarray(p1["b"]), np.asarray(p2["b"]),
                               atol=1e-5)


def test_adamw_master_weights_do_not_alias_f32_params():
    params = {"r": jnp.ones((3,), jnp.float32)}
    state = adamw_init(params)
    assert state.master["r"] is not params["r"] or \
        state.master["r"].unsafe_buffer_pointer() != params["r"].unsafe_buffer_pointer()


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup=10,
                               total=100)) for s in range(100)]
    assert lrs[0] > 0                      # step 0 must move params
    assert abs(lrs[9] - 1.0) < 1e-6        # end of warmup == peak
    assert all(a >= b - 1e-6 for a, b in zip(lrs[10:], lrs[11:]))  # decay
    assert lrs[-1] >= 0.1 * 0.9            # floor


def test_global_norm():
    t = {"a": jnp.ones((3,)), "b": -2.0 * jnp.ones((4,))}
    np.testing.assert_allclose(float(global_norm(t)),
                               np.sqrt(3 + 4.0 * 4), rtol=1e-6)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,), jnp.int32)]}
    path = str(tmp_path / "ckpt")
    save_pytree(path, tree)
    zeros = jax.tree.map(jnp.zeros_like, tree)
    restored = restore_pytree(path, zeros)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ckpt2")
    save_pytree(path, {"a": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        restore_pytree(path, {"a": jnp.ones((3, 3))})


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_lm_batches_learnable_and_sharded():
    it = synthetic_lm_batches(KEY, vocab=64, batch=4, seq=16)
    b1 = next(it)
    b2 = next(it)
    assert b1.tokens.shape == (4, 16)
    assert b1.labels.shape == (4, 16)
    # labels are next-token shifted, last masked
    np.testing.assert_array_equal(np.asarray(b1.labels[:, :-1]),
                                  np.asarray(b1.tokens[:, 1:]))
    assert bool(jnp.all(b1.labels[:, -1] == -1))
    assert not bool(jnp.all(b1.tokens == b2.tokens))   # stream advances


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_fit_spec_drops_nondividing_axes():
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    m = FakeMesh()
    assert fit_spec((24, 128), ("model", None), m) == P(None, None)
    assert fit_spec((32, 128), ("model", None), m) == P("model", None)
    # right alignment adds leading None for stacked params
    assert fit_spec((8, 32, 128), ("model", None), m) == P(None, "model", None)


def test_fit_first_fallback_chain():
    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")
    m = FakeMesh()
    # vocab 49155 not divisible -> falls back to d-over-(data,model)
    spec = fit_first((49155, 2048), (("model", "data"),
                                     (None, ("data", "model"))), m)
    assert spec == P(None, ("data", "model"))


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

def test_analyze_hlo_scan_flops_exact():
    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y
    s = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(s, s).compile()
    ana = analyze_hlo(compiled.as_text(), default_trip=7)
    assert ana["flops"] == 7 * 2 * 64 ** 3


def test_roofline_bottleneck_selection():
    t = roofline(flops=197e12, bytes_accessed=1.0, coll_bytes=1.0)
    assert t["bottleneck"] == "compute"
    t = roofline(flops=1.0, bytes_accessed=819e9 * 5, coll_bytes=1.0)
    assert t["bottleneck"] == "memory"
    t = roofline(flops=1.0, bytes_accessed=1.0, coll_bytes=50e9 * 5)
    assert t["bottleneck"] == "collective"


# ---------------------------------------------------------------------------
# config registry
# ---------------------------------------------------------------------------

def test_all_assigned_configs_match_spec():
    spec = {
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, None, 151936),
        "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "mamba2-1.3b": (48, 2048, 64, 0, 0, 50280),
    }
    for arch, (L, d, nh, kv, ff, v) in spec.items():
        cfg = get_config(arch)
        assert cfg.n_layers == L, arch
        assert cfg.d_model == d, arch
        assert cfg.n_heads == nh, arch
        assert cfg.n_kv_heads == kv, arch
        if ff is not None:
            assert cfg.d_ff == ff, arch
        assert cfg.vocab == v, arch
    # MoE details
    q = get_config("qwen3-moe-30b-a3b").moe
    assert (q.n_experts, q.top_k, q.n_shared) == (128, 8, 0)
    ds = get_config("deepseek-moe-16b").moe
    assert (ds.n_experts, ds.top_k, ds.n_shared, ds.d_expert) == (64, 6, 2, 1408)
    mm = get_config("mamba2-1.3b").ssd
    assert mm.state_dim == 128


def test_smoke_configs_are_reduced():
    for arch in ASSIGNED:
        cfg = smoke(get_config(arch))
        assert cfg.n_layers <= 3
        assert cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.n_experts <= 4
