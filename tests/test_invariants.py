"""Tier-1 self-hosting gate for the repro static-analysis pass.

Two halves:

* **Self-hosting** — run all three engines over `src/` and
  `benchmarks/` exactly as `make lint` does and require zero findings.
  Any new violation of a standing invariant (DESIGN.md sections 13 and
  17) fails the suite, not just the standalone lint target.
* **Fixtures** — each known-bad file under `tests/fixtures/lint/`
  encodes one violation class; the linter must report the specific
  finding code (not merely "some finding") and must not drown it in
  false positives on the surrounding lines.
"""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import pytest

from tools.repro_lint import run
from tools.repro_lint.cachecheck import check_cache_file
from tools.repro_lint.concurrency import lint_concurrency_file
from tools.repro_lint.contracts import check_kernel_geometry
from tools.repro_lint.findings import CODES
from tools.repro_lint.invariants import lint_file

REPO = Path(__file__).resolve().parents[1]
FIXTURES = REPO / "tests" / "fixtures" / "lint"


def codes(findings):
    return sorted({f.code for f in findings})


# --- self-hosting ---------------------------------------------------------

def test_repo_is_lint_clean():
    findings = run([str(REPO / "src"), str(REPO / "benchmarks")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_cli_exit_codes():
    # the same contract `make lint` relies on: 0 clean, 1 on findings
    env_paths = [str(REPO / "src")]
    clean = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--no-contracts",
         *env_paths],
        cwd=REPO, capture_output=True, text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--no-contracts",
         str(FIXTURES / "bad_import_boundary.py")],
        cwd=REPO, capture_output=True, text=True)
    assert dirty.returncode == 1
    assert "RL101" in dirty.stdout


def test_cache_cli_never_imports_jax():
    probe = ("import sys, tools.repro_lint.cachecheck as c; "
             "sys.exit(1 if 'jax' in sys.modules else 0)")
    r = subprocess.run([sys.executable, "-c", probe],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, "cachecheck must stay jax-free"


def test_concurrency_engine_never_imports_jax():
    # the `--concurrency` make-lint leg must stay a stdlib-only pass,
    # like Engine 1 — both importing the module AND running it
    probe = ("import sys; "
             "from tools.repro_lint.concurrency import check_concurrency; "
             "check_concurrency(['src/repro/stream', 'src/repro/testing']); "
             "sys.exit(1 if 'jax' in sys.modules else 0)")
    r = subprocess.run([sys.executable, "-c", probe],
                       cwd=REPO, capture_output=True, text=True)
    assert r.returncode == 0, "concurrency engine must stay jax-free"


def test_concurrency_cli_exit_codes():
    clean = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--concurrency",
         str(REPO / "src"), str(REPO / "benchmarks")],
        cwd=REPO, capture_output=True, text=True)
    assert clean.returncode == 0, clean.stdout + clean.stderr
    dirty = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--concurrency",
         str(FIXTURES / "concurrency" / "bad_worker_state.py")],
        cwd=REPO, capture_output=True, text=True)
    assert dirty.returncode == 1
    assert "RL403" in dirty.stdout
    usage = subprocess.run(
        [sys.executable, "-m", "tools.repro_lint", "--concurrency"],
        cwd=REPO, capture_output=True, text=True)
    assert usage.returncode == 2


def test_every_code_documented():
    assert all(code.startswith("RL") for code in CODES)
    for findings_source in ("RL101", "RL105", "RL107", "RL108", "RL109",
                            "RL201", "RL210", "RL212", "RL301", "RL303",
                            "RL401", "RL402", "RL403", "RL404", "RL405"):
        assert findings_source in CODES


# --- Engine 1 fixtures ----------------------------------------------------

def test_fixture_import_boundary():
    f = lint_file(FIXTURES / "bad_import_boundary.py")
    assert codes(f) == ["RL101"]
    assert len(f) == 3          # shard_map import, make_mesh, lax.psum


def test_fixture_ops_convention():
    f = lint_file(FIXTURES / "kernels" / "bad_ops" / "ops.py")
    assert codes(f) == ["RL102", "RL103", "RL104"]


def test_fixture_autotune_key():
    f = lint_file(FIXTURES / "bad_autotune_key.py")
    assert codes(f) == ["RL105"]
    assert len(f) == 2          # the namespaced write must NOT fire


def test_fixture_config_mutation():
    f = lint_file(FIXTURES / "bad_config_mutation.py")
    assert codes(f) == ["RL106"]


def test_fixture_tracer_hazard():
    f = lint_file(FIXTURES / "bad_tracer_hazard.py")
    assert codes(f) == ["RL107"]
    assert len(f) == 2          # `if g > 0` and `float(g)`


def test_fixture_exception_swallow():
    f = lint_file(FIXTURES / "bad_exception_swallow.py")
    assert codes(f) == ["RL109"]
    # silent `pass` + bare-except `return None`; the re-raising,
    # obs-recording, traceback-capturing, and narrowed handlers must
    # NOT fire
    assert len(f) == 2


def test_fixture_obs_in_jit():
    f = lint_file(FIXTURES / "bad_obs_in_jit.py")
    assert codes(f) == ["RL108"]
    # the jit root's inc + the reachable helper's span context manager;
    # the eager report() inc must NOT fire
    assert len(f) == 2
    assert not any("fixture.reports" in x.message or "'report'" in x.message
                   for x in f)


# --- Engine 3 fixtures (concurrency contracts) ----------------------------

CFIX = FIXTURES / "concurrency"


def test_fixture_undeclared_policy():
    f = lint_concurrency_file(CFIX / "bad_undeclared.py")
    assert codes(f) == ["RL401"]
    # thread spawner without a policy, the uncovered attribute, the
    # malformed grammar, and the attribute the malformed entry was
    # meant to cover
    assert len(f) == 4


def test_fixture_publish_site():
    f = lint_concurrency_file(CFIX / "bad_publish_site.py")
    assert codes(f) == ["RL402"]
    assert len(f) == 2          # off-site write + on-site RMW
    msgs = " ".join(x.message for x in f)
    assert "read-modify-writes" in msgs and "'sneak'" in msgs
    # the clean publish at its declared site must NOT fire
    assert "'publish'" not in msgs


def test_fixture_compound_mutation():
    f = lint_concurrency_file(CFIX / "bad_compound_mutation.py")
    assert codes(f) == ["RL402"]
    # subscript + compound mutation, immutable write, unlocked touch
    assert len(f) == 4
    msgs = " ".join(x.message for x in f)
    assert "'record'" not in msgs    # the locked access must NOT fire


def test_fixture_worker_state():
    f = lint_concurrency_file(CFIX / "bad_worker_state.py")
    assert codes(f) == ["RL403"]
    assert len(f) == 2          # stop()'s read and write of _carry
    assert all("'stop'" in x.message for x in f)
    # _run/_drain sit inside the worker's call graph: must NOT fire


def test_fixture_lock_blocking():
    f = lint_concurrency_file(CFIX / "bad_lock_blocking.py")
    assert codes(f) == ["RL404"]
    # solve + result() + get() + join(), all under the declared lock;
    # the timeout-bounded variants must NOT fire
    assert len(f) == 4
    assert all("'refresh'" in x.message for x in f)


def test_fixture_dropped_future():
    f = lint_concurrency_file(CFIX / "bad_dropped_future.py")
    assert codes(f) == ["RL405"]
    assert len(f) == 2          # never handed off + raise before handoff
    msgs = " ".join(x.message for x in f)
    assert "'lost'" in msgs
    # the validate-then-mint pattern in clean() must NOT fire


def test_pre_fix_serving_fixture_is_flagged():
    # the executable pre-fix front (tests/fixtures/serving_pre_fix.py,
    # replayed dynamically in test_interleave.py) must also fall to the
    # STATIC checker: its stop() touches worker-owned state
    f = lint_concurrency_file(REPO / "tests" / "fixtures"
                              / "serving_pre_fix.py")
    assert codes(f) == ["RL403"]
    assert len(f) == 3          # the condition read, the append read,
    assert all("_carry" in x.message for x in f)   # the clearing write


# --- Engine 2 geometry fixture -------------------------------------------

def test_fixture_blockspec_geometry():
    path = FIXTURES / "kernels" / "bad_geom" / "kernel.py"
    f = check_kernel_geometry(path, str(path))
    assert "RL201" in codes(f)
    assert "RL202" in codes(f)
    # RL202 must name the unguarded tile params, not the array dims only
    tile_msgs = [x.message for x in f if x.code == "RL202"]
    assert any("'bn'" in m or "'bp'" in m for m in tile_msgs)


# --- cache checker fixtures ----------------------------------------------

def test_fixture_bad_cache_json():
    f = check_cache_file(FIXTURES / "bad_cache.json")
    got = codes(f)
    assert got == ["RL301", "RL302", "RL303"]
    by_code = {}
    for x in f:
        by_code.setdefault(x.code, []).append(x.message)
    assert len(by_code["RL301"]) == 1          # the bare key
    assert len(by_code["RL302"]) == 2          # unknown ns + wrong dims
    assert len(by_code["RL303"]) == 1          # wrong value arity
    # legacy int value is legal for fista_step only — no finding for it
    assert not any("fista_step" in m for m in by_code["RL303"])


def test_missing_cache_file_is_clean(tmp_path):
    assert check_cache_file(tmp_path / "nope.json") == []


def test_committed_cache_if_any_is_clean():
    cache = REPO / ".cache" / "autotune.json"
    findings = check_cache_file(cache)
    assert findings == [], "\n".join(f.render() for f in findings)


def test_malformed_cache_root(tmp_path):
    bad = tmp_path / "autotune.json"
    bad.write_text(json.dumps([1, 2, 3]))
    assert codes(check_cache_file(bad)) == ["RL302"]


# --- contract grid sanity -------------------------------------------------

def test_contract_grid_runs_clean():
    # Engine 2's dispatch-contract pass over the real kernels package;
    # geometry is exercised by test_repo_is_lint_clean too, but this
    # pins the jax-importing half in isolation for faster bisection.
    from tools.repro_lint.contracts import check_contracts
    findings = check_contracts([str(REPO / "src")])
    assert findings == [], "\n".join(f.render() for f in findings)


def test_budget_model_rejects_known_bust():
    # the static byte model itself must keep rejecting the PR-5
    # regression point: p=8168 with full-lane bp busts 8 MB
    from repro.kernels.logistic_grad.ops import (
        LOGISTIC_VMEM_BUDGET, kernel_vmem_bytes)
    assert kernel_vmem_bytes(8168, 1024, 8168) > LOGISTIC_VMEM_BUDGET
    assert kernel_vmem_bytes(128, 128, 128) <= LOGISTIC_VMEM_BUDGET


if __name__ == "__main__":
    sys.exit(pytest.main([__file__, "-v"]))
