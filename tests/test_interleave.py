"""Concurrency-contract tier: deterministic interleaving tests.

The static half of the contract lives in `tools/repro_lint/
concurrency.py` (RL4xx, pinned in test_invariants.py); this file is
the dynamic half (DESIGN.md §17):

* replay the REAL pre-fix `ServingFront.stop()`/worker race on the
  preserved old lifecycle bodies (`tests/fixtures/serving_pre_fix.py`)
  as one exact gated schedule — no sleeps, no luck — showing a live
  worker's future being failed under it and a second worker spawned
  against the un-stopped zombie;
* run the SAME schedule against the fixed front and prove every
  admitted request resolves, on exactly one worker, with fresh
  lifecycle state per start;
* sweep 200 seeded adversarial schedules (scheduler-forced context
  switches at every `_worker`/`_stop`/`_carry` touch) and require
  bitwise-coherent results: every future either resolves to the
  published generation's exact scores or fails with the stop error —
  never a hang, never a torn result.
"""
from __future__ import annotations

import importlib.util
import random
import threading
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.stream.serve import ModelGeneration, ServeResult, ServingFront
from repro.stream.service import _predict_shared
from repro.testing import Gates, InterleaveScheduler, instrument

REPO = Path(__file__).resolve().parents[1]

M, P, GENERATION = 3, 5, 7


def _load_pre_fix_front():
    path = REPO / "tests" / "fixtures" / "serving_pre_fix.py"
    spec = importlib.util.spec_from_file_location("serving_pre_fix", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.PreFixServingFront


class _TinyService:
    """The minimal `.p` + `.serving()` surface `ServingFront` needs,
    publishing one fixed real `ModelGeneration`. With `gates`, every
    `serving()` call parks at the named gate so a test can hold the
    worker mid-`_process` at an exact, named point."""

    def __init__(self, gates: Gates | None = None, gate: str = "serving"):
        beta = jnp.asarray(
            np.arange(M * P, dtype=np.float32).reshape(M, P) * 0.1 + 1.0)
        support = jnp.ones((P,), dtype=bool)
        self.p = P
        self._snap = ModelGeneration(beta, support, GENERATION)
        self._gates = gates
        self._gate = gate

    def serving(self) -> ModelGeneration:
        if self._gates is not None:
            self._gates.reach(self._gate)
        return self._snap


X0 = np.linspace(-1.0, 1.0, P).astype(np.float32)


def _reference_column(svc: _TinyService) -> np.ndarray:
    """The exact (m, 1) scores a single-row X0 request must carry: in
    the `"np,tp->tn"` einsum each output column depends only on its own
    input row, and every microbatch of X0 rows pads to the same (8, P)
    shape — so this one column is the bitwise oracle for EVERY request
    in the sweep, whatever batch it landed in."""
    X = np.zeros((8, P), dtype=np.float32)
    X[0] = X0
    return np.asarray(_predict_shared(svc._snap.beta_tilde,
                                      jnp.asarray(X)))[:, :1]


# --- the pre-fix race, replayed exactly ------------------------------------

def test_pre_fix_stop_race_replays_deterministically():
    """One gated schedule, zero randomness: submit A (worker parks
    mid-batch), submit B, stop with a too-short timeout, restart. The
    PR-9 lifecycle then exhibits all three bug symptoms at once."""
    PreFix = _load_pre_fix_front()
    gates = Gates()
    svc = _TinyService(gates=gates)
    front = PreFix(svc, max_batch=1, max_delay_ms=0.5, poll_s=0.01)

    front.start()
    zombie = front._worker
    ev0 = front._stop
    fut_a = front.submit(X0)
    gates.wait_reached("serving")      # worker is parked inside batch A
    fut_b = front.submit(X0)           # queued behind the parked batch

    front.stop(timeout=0.05)           # join expires: worker still alive

    # symptom 1: B was failed even though a live worker owned the queue
    assert isinstance(fut_b.exception(timeout=1), RuntimeError)
    # symptom 2: the handle was dropped while the worker was alive...
    assert front._worker is None and zombie.is_alive()

    front.start()                      # ...so start() spawns a SECOND
    second = front._worker             # worker against the zombie
    assert second is not zombie and second.is_alive()
    # symptom 3: start() cleared the SHARED stop event out from under
    # the half-stopped zombie
    assert front._stop is ev0 and not ev0.is_set()

    gates.release("serving")
    # the zombie finishes batch A fine — and then keeps serving,
    # because the flag that told it to stop was cleared
    res = fut_a.result(timeout=5)
    assert res.generation == GENERATION
    zombie.join(timeout=0.2)
    assert zombie.is_alive(), "pre-fix zombie must outlive its stop()"
    assert second.is_alive()           # two workers race one queue

    # cleanup: stop both workers for real
    ev0.set()
    front._q.put(None)
    front._q.put(None)
    zombie.join(5)
    second.join(5)
    assert not zombie.is_alive() and not second.is_alive()


def test_fixed_front_survives_the_same_schedule():
    """The exact schedule above, on the fixed front: the timed-out
    stop() reclaims nothing, B still resolves (drain-and-stop), the
    restart waits the old worker out and mints fresh lifecycle state,
    and exactly one worker remains."""
    gates = Gates()
    svc = _TinyService(gates=gates)
    front = ServingFront(svc, max_batch=1, max_delay_ms=0.5, poll_s=0.01)
    ref = _reference_column(svc)

    front.start()
    zombie = front._worker
    ev0 = front._stop
    fut_a = front.submit(X0)
    gates.wait_reached("serving")
    fut_b = front.submit(X0)

    assert front.stop(timeout=0.05) is False
    # nothing reclaimed under a live worker: handle kept, B untouched,
    # the worker's own (set) stop event left in place
    assert front._worker is zombie
    assert not fut_b.done()
    assert front._stop is ev0 and ev0.is_set()

    # two gate passes: batch A, then B via the worker's final sweep
    gates.release("serving", 2)

    front.start()                      # joins the zombie out, then spawns
    assert not zombie.is_alive()
    assert front._worker is not zombie and front._worker.is_alive()
    # fresh lifecycle state: new event published, the old one still set
    assert front._stop is not ev0 and ev0.is_set()
    assert not front._stop.is_set()

    # BOTH admitted requests resolved, bitwise against the oracle
    for fut in (fut_a, fut_b):
        res: ServeResult = fut.result(timeout=5)
        assert res.generation == GENERATION
        np.testing.assert_array_equal(res.scores, ref)

    assert front.stop() is True
    assert front._worker is None


def test_stopped_front_rejects_new_submissions():
    svc = _TinyService()
    front = ServingFront(svc, max_batch=1, poll_s=0.01)
    front.start()
    assert front.stop() is True
    with pytest.raises(RuntimeError, match="not running"):
        front.submit(X0)


# --- the harness itself -----------------------------------------------------

def test_gates_timeout_is_loud():
    gates = Gates()
    with pytest.raises(TimeoutError, match="never released"):
        gates.reach("nobody-home", timeout=0.01)


def test_scheduler_replays_its_decisions():
    """Same seed, same yield sequence -> same schedule decisions; a
    different seed diverges. (Idents differ across runs; the DECISION
    SEQUENCE — which position in the ring got the token — is what must
    replay.)"""
    def decisions(seed: int):
        sched = InterleaveScheduler(seed, max_wait_s=0.01)
        sched.register()
        done = threading.Event()
        go = threading.Event()

        def sidekick():
            go.wait()
            while not done.is_set():
                sched.yield_point("side")
        # two sidekicks, so each yield is a real 2-way seeded choice;
        # main registers them in a FIXED order (ring order is part of
        # what the seed replays) before letting them run
        ts = [threading.Thread(target=sidekick, daemon=True)
              for _ in range(2)]
        for t in ts:
            t.start()
        for t in ts:
            sched.register(t)
        go.set()
        order = {threading.get_ident(): 0}
        for i, t in enumerate(ts):
            order[t.ident] = i + 1
        for _ in range(20):
            sched.yield_point("main")
        done.set()
        sched.close()
        for t in ts:
            t.join(5)
        return [(tag, order[ident]) for tag, ident in sched.schedule
                if tag == "main"]

    a, b = decisions(1234), decisions(1234)
    assert a == b and len(a) == 20
    assert decisions(99) != a


# --- seeded adversarial sweep ----------------------------------------------

@pytest.mark.parametrize("seed_block", range(8))
def test_seeded_schedules_stay_bitwise_coherent(seed_block):
    """200 seeded schedules (25 per parametrized block), each forcing
    context switches at every `_worker`/`_stop`/`_carry` touch while a
    seeded op script submits, stops, and restarts the front. Invariant:
    every admitted future terminates, and terminates EITHER with the
    stop error OR with bitwise-exact scores under the published
    generation — no hangs, no torn reads, no cross-generation mixes."""
    svc = _TinyService()
    ref = _reference_column(svc)

    for seed in range(seed_block * 25, (seed_block + 1) * 25):
        sched = InterleaveScheduler(seed, max_wait_s=0.02)
        Front = instrument(ServingFront, ("_worker", "_stop", "_carry"),
                           sched)
        front = Front(svc, max_batch=4, max_delay_ms=0.5, poll_s=0.005)
        sched.register()
        rng = random.Random(seed)
        futures = []
        front.start()
        for _ in range(8):
            op = rng.choice(("submit", "submit", "submit", "stop",
                             "start"))
            if op == "submit":
                try:
                    futures.append(front.submit(X0))
                except RuntimeError:
                    pass               # front stopped — legal refusal
            elif op == "stop":
                front.stop(timeout=rng.choice((0.0, 0.01)))
            else:
                front.start()
        sched.close()
        while front.stop(timeout=1.0) is False:
            pass
        assert front._worker is None

        for fut in futures:
            exc = fut.exception(timeout=5)   # also proves it terminated
            if exc is not None:
                assert isinstance(exc, RuntimeError), (seed, exc)
                assert "serving front stopped" in str(exc)
                continue
            res: ServeResult = fut.result()
            assert res.generation == GENERATION, seed
            np.testing.assert_array_equal(res.scores, ref,
                                          err_msg=f"seed={seed}")
