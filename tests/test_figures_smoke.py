"""Golden-value smoke tests for the paper figure drivers.

The fig1/fig2 `--smoke` sweeps were previously only exercised by the
CI bench job, which checks nothing about their OUTPUT — a silent
regression in `eval_*_methods` (a mistuned grid, a broken method
wiring, a metric typo) would keep printing plausible rows forever.
These tests drive one point per sweep through the real driver
(`main()`, same code path as `--smoke`, reduced to one point to stay
test-sized) and pin the headline metrics to committed bands around the
seeded golden values, with ordering invariants the paper's figures
assert visually (refit beats raw, DSML tracks group lasso at the
headline point).

Bands are ±50% around the committed seed-0 values — wide enough for
float drift across jax versions, narrow enough that a method swap or a
broken tuning grid (typically 2-10x error shifts) trips them.
"""
import json

from benchmarks import fig1_regression as fig1
from benchmarks import fig2_classification as fig2

METHODS = {"lasso", "group_lasso", "refit_group_lasso", "icap",
           "dsml", "refit_dsml"}


def _check_structure(results, rows, points):
    """`results` IS the persisted artifact (the tests read it back from
    disk, which is itself the check that main() wrote valid JSON where
    it promised); here we pin its internal structure and the printed
    row contract."""
    assert set(results) == {"vary_n", "vary_m"}
    for sweep_name, x in points:
        methods = results[sweep_name][x]
        assert set(methods) == METHODS
        for met in methods.values():
            assert set(met) == {"hamming", "est_err", "pred_err"}
    assert len(rows) == 2 * len(METHODS)
    assert all("hamming=" in r for r in rows)


def test_fig1_smoke_golden_metrics(tmp_path):
    rows = fig1.main(n_runs=1, iters=200, out_dir=str(tmp_path),
                     vary_n=(120,), vary_m=(5,))
    with open(tmp_path / "fig1_regression.json") as f:
        results = json.load(f)
    _check_structure(results, rows, [("vary_n", "120"), ("vary_m", "5")])

    # headline point (m=10, n=120): golden seed-0 values
    # dsml: hamming 0, est 4.37, pred 0.207; refit_dsml est 2.84
    pt = results["vary_n"]["120"]
    assert pt["dsml"]["hamming"] <= 1
    assert pt["group_lasso"]["hamming"] <= 1
    assert 2.9 < pt["dsml"]["est_err"] < 6.6
    assert pt["dsml"]["pred_err"] < 0.45
    assert 1.9 < pt["refit_dsml"]["est_err"] < 4.3
    # figure-shape invariants: refitting improves prediction, the
    # one-round dsml tracks the centralized group lasso
    assert pt["refit_dsml"]["pred_err"] <= pt["dsml"]["pred_err"]
    assert pt["dsml"]["est_err"] <= pt["group_lasso"]["est_err"]
    assert pt["dsml"]["est_err"] <= pt["lasso"]["est_err"]


def test_fig2_smoke_golden_metrics(tmp_path):
    rows = fig2.main(n_runs=1, iters=250, out_dir=str(tmp_path),
                     vary_n=(150,), vary_m=(3,))
    with open(tmp_path / "fig2_classification.json") as f:
        results = json.load(f)
    _check_structure(results, rows, [("vary_n", "150"), ("vary_m", "3")])

    # headline point (m=10, n=150): golden seed-0 values
    # dsml: hamming 0, pred 0.088; refit_dsml est 10.9; lasso pred 0.461
    pt = results["vary_n"]["150"]
    assert pt["dsml"]["hamming"] <= 1
    assert pt["dsml"]["pred_err"] < 0.15
    assert 7.0 < pt["refit_dsml"]["est_err"] < 16.5
    assert pt["dsml"]["pred_err"] < pt["lasso"]["pred_err"]
    assert pt["refit_dsml"]["pred_err"] <= pt["dsml"]["pred_err"] + 0.02
