"""Golden-value smoke tests for the paper figure drivers.

The fig1/fig2 `--smoke` sweeps were previously only exercised by the
CI bench job, which checks nothing about their OUTPUT — a silent
regression in `eval_*_methods` (a mistuned grid, a broken method
wiring, a metric typo) would keep printing plausible rows forever.
These tests drive one point per sweep through the real driver
(`main()`, same code path as `--smoke`, reduced to one point to stay
test-sized) and pin the headline metrics to committed bands around the
seeded golden values, with ordering invariants the paper's figures
assert visually (refit beats raw, DSML tracks group lasso at the
headline point).

Bands are ±50% around the committed seed-0 values — wide enough for
float drift across jax versions, narrow enough that a method swap or a
broken tuning grid (typically 2-10x error shifts) trips them.
"""
import json

from benchmarks import fig1_regression as fig1
from benchmarks import fig2_classification as fig2
from benchmarks import largep_logistic as largep

METHODS = {"lasso", "group_lasso", "refit_group_lasso", "icap",
           "dsml", "refit_dsml"}


def _check_structure(results, rows, points):
    """`results` IS the persisted artifact (the tests read it back from
    disk, which is itself the check that main() wrote valid JSON where
    it promised); here we pin its internal structure and the printed
    row contract."""
    assert set(results) == {"vary_n", "vary_m"}
    for sweep_name, x in points:
        methods = results[sweep_name][x]
        assert set(methods) == METHODS
        for met in methods.values():
            assert set(met) == {"hamming", "est_err", "pred_err"}
    assert len(rows) == 2 * len(METHODS)
    assert all("hamming=" in r for r in rows)


def test_fig1_smoke_golden_metrics(tmp_path):
    rows = fig1.main(n_runs=1, iters=200, out_dir=str(tmp_path),
                     vary_n=(120,), vary_m=(5,))
    with open(tmp_path / "fig1_regression.json") as f:
        results = json.load(f)
    _check_structure(results, rows, [("vary_n", "120"), ("vary_m", "5")])

    # headline point (m=10, n=120): golden seed-0 values
    # dsml: hamming 0, est 4.37, pred 0.207; refit_dsml est 2.84
    pt = results["vary_n"]["120"]
    assert pt["dsml"]["hamming"] <= 1
    assert pt["group_lasso"]["hamming"] <= 1
    assert 2.9 < pt["dsml"]["est_err"] < 6.6
    assert pt["dsml"]["pred_err"] < 0.45
    assert 1.9 < pt["refit_dsml"]["est_err"] < 4.3
    # figure-shape invariants: refitting improves prediction, the
    # one-round dsml tracks the centralized group lasso
    assert pt["refit_dsml"]["pred_err"] <= pt["dsml"]["pred_err"]
    assert pt["dsml"]["est_err"] <= pt["group_lasso"]["est_err"]
    assert pt["dsml"]["est_err"] <= pt["lasso"]["est_err"]


def test_fig2_smoke_golden_metrics(tmp_path):
    rows = fig2.main(n_runs=1, iters=250, out_dir=str(tmp_path),
                     vary_n=(150,), vary_m=(3,))
    with open(tmp_path / "fig2_classification.json") as f:
        results = json.load(f)
    _check_structure(results, rows, [("vary_n", "150"), ("vary_m", "3")])

    # headline point (m=10, n=150): golden seed-0 values
    # dsml: hamming 0, pred 0.088; refit_dsml est 10.9; lasso pred 0.461
    pt = results["vary_n"]["150"]
    assert pt["dsml"]["hamming"] <= 1
    assert pt["dsml"]["pred_err"] < 0.15
    assert 7.0 < pt["refit_dsml"]["est_err"] < 16.5
    assert pt["dsml"]["pred_err"] < pt["lasso"]["pred_err"]
    assert pt["refit_dsml"]["pred_err"] <= pt["dsml"]["pred_err"] + 0.02


def test_largep_logistic_smoke_golden_metrics(tmp_path):
    """ISSUE 5: the p = 8192 sweep point through the real driver — the
    paper's p >> n regime past the old full-lane kernel cliff. Pins the
    seed-0 recovery metrics (hamming 3, est 12.2) to ±50% bands AND the
    routing contract: the shape stays on the feature-tiled kernel path
    (routed_oracle False, bp < p) with kernel iterates matching the
    oracle's to 1e-5."""
    rows = largep.main(largep.SMOKE_P, out_dir=str(tmp_path), iters=100)
    with open(tmp_path / "largep_logistic.json") as f:
        results = json.load(f)
    assert len(rows) == 1 and "kernel_dev=" in rows[0]
    met = results["8192"]
    assert not met["routed_oracle"]          # acceptance: on-kernel at 8192
    assert met["bp"] < 8192 and 8192 % met["bp"] == 0   # genuinely tiled
    assert met["kernel_dev"] <= 1e-5         # kernel path == oracle path
    assert met["hamming"] <= 6               # golden 3
    assert 6.0 < met["est_err"] < 18.3       # golden 12.2


def test_stream_online_smoke_golden_metrics():
    """Golden bands for the examples/stream_online.py headline metrics
    (ROADMAP candidate): the --smoke demo through the real driver —
    deterministic seed 0, so the refit cadence is pinned exactly and
    the post-shift recovery metrics to ±50% bands around the committed
    seed-0 values (final_hamming 2, final_est_err 0.985)."""
    from examples.stream_online import main as stream_main
    met = stream_main(["--smoke"])
    assert met["generations"] == 5           # drift-adaptive cadence, exact
    assert met["refits_during_stream"] == 4
    assert met["final_hamming"] <= 4         # golden 2: support re-acquired
    assert 0.49 < met["final_est_err"] < 1.48
    assert 100 < met["samples_seen"] < 300   # decay-discounted effective n
