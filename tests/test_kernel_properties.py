"""Property tests for the fused logistic-gradient and rank-n update
kernels: interpret-mode pallas == jnp oracle to 1e-5 over hypothesis-
drawn shapes, block sizes, and dtypes — including non-divisor block
edges, where the dispatcher must clip the tile to a legal divisor or
route the ragged shape to the oracle without the caller noticing.
"""
import pytest

pytest.importorskip("hypothesis", reason="optional test dep (pip install .[test])")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.logistic_grad.ops import (
    is_ragged_samples, logistic_grad, logistic_grad_unfused,
)
from repro.kernels.logistic_grad.ref import logistic_grad_ref
from repro.kernels.rank_update.ops import rank_update, rank_update_unfused
from repro.kernels.rank_update.ref import rank_update_ref

# multiples of 8 keep the kernel path active; the *_any strategies also
# draw ragged sizes to exercise the oracle routing. Blocks deliberately
# include non-divisors of every size (e.g. 48 against n=80) so the
# divisor-clip path is always on the table.
DIMS_8 = st.sampled_from([8, 16, 24, 32, 40, 64, 80])
DIMS_ANY = st.sampled_from([5, 8, 12, 16, 30, 33, 64])
BLOCKS = st.sampled_from([8, 16, 24, 32, 48, 128])
DTYPES = st.sampled_from([jnp.float32, jnp.bfloat16])


def _tol(dtype):
    return 1e-5 if dtype == jnp.float32 else 0.05


def _logistic_case(m, n, p, dtype, seed):
    k = jax.random.PRNGKey(seed)
    Xs = jax.random.normal(k, (m, n, p), dtype)
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(seed + 1), (m, n))
                  ).astype(dtype)
    B = (jax.random.normal(jax.random.PRNGKey(seed + 2), (m, p)) * 0.3
         ).astype(dtype)
    return Xs, ys, B


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 4), n=DIMS_8, p=DIMS_8, block=BLOCKS,
       dtype=DTYPES, seed=st.integers(0, 3))
def test_logistic_grad_fused_matches_oracle(m, n, p, block, dtype, seed):
    Xs, ys, B = _logistic_case(m, n, p, dtype, seed)
    out = logistic_grad(Xs, ys, B, block=block, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 3), n=DIMS_8, p=st.sampled_from([64, 96, 128, 256]),
       bn=BLOCKS, bp=st.sampled_from([8, 24, 32, 48, 100, 128]),
       dtype=DTYPES, seed=st.integers(0, 3))
def test_logistic_grad_feature_tiled_pairs_match_oracle(m, n, p, bn, bp,
                                                        dtype, seed):
    """ISSUE 5: explicit (bn, bp) pairs — non-divisor requests of both
    axes included — must clip to legal tiles (or route to the oracle)
    and match the oracle regardless; bp < p exercises the two-phase
    feature-tiled sweep."""
    Xs, ys, B = _logistic_case(m, n, p, dtype, seed)
    out = logistic_grad(Xs, ys, B, block=(bn, bp), interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype))


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 2), n=DIMS_8, p=st.sampled_from([96, 128, 192]),
       bn=BLOCKS, bp=st.sampled_from([16, 24, 48, 100]),
       seed=st.integers(0, 3))
def test_logistic_grad_unfused_feature_tiled_matches_fused(m, n, p, bn,
                                                           bp, seed):
    """The two-dispatch twin shares the (bn, bp) clipping and the f32
    accumulation order with the fused kernel."""
    Xs, ys, B = _logistic_case(m, n, p, jnp.float32, seed)
    fused = logistic_grad(Xs, ys, B, block=(bn, bp), interpret=True)
    unfused = logistic_grad_unfused(Xs, ys, B, block=(bn, bp),
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 3), n=DIMS_ANY, p=DIMS_ANY,
       block=BLOCKS, seed=st.integers(0, 3))
def test_logistic_grad_ragged_shapes_route_to_oracle(m, n, p, block, seed):
    """Any (n, p) — ragged included — must return oracle-exact output;
    the dispatcher owns the routing, callers never pre-check."""
    Xs, ys, B = _logistic_case(m, n, p, jnp.float32, seed)
    out = logistic_grad(Xs, ys, B, block=block, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    if is_ragged_samples(n, p):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
    else:
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 3), n=DIMS_8, p=DIMS_8, block=BLOCKS,
       seed=st.integers(0, 3))
def test_logistic_grad_unfused_matches_oracle(m, n, p, block, seed):
    """The two-dispatch bench baseline obeys the same contract."""
    Xs, ys, B = _logistic_case(m, n, p, jnp.float32, seed)
    out = logistic_grad_unfused(Xs, ys, B, block=block, interpret=True)
    ref = logistic_grad_ref(Xs, ys, B)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def _rank_case(m, n, p, dtype, seed, weighted):
    k = jax.random.PRNGKey(seed)
    Xs = jax.random.normal(k, (m, n, p), dtype)
    ys = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, n), dtype)
    w = None
    if weighted:
        w = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (m, n))
             + 0.25).astype(dtype)
    return Xs, ys, w


@settings(max_examples=25, deadline=None)
@given(m=st.integers(1, 4), n=DIMS_8, p=DIMS_8, bp=BLOCKS, bn=BLOCKS,
       dtype=DTYPES, weighted=st.booleans(), seed=st.integers(0, 3))
def test_rank_update_fused_matches_oracle(m, n, p, bp, bn, dtype,
                                          weighted, seed):
    Xs, ys, w = _rank_case(m, n, p, dtype, seed, weighted)
    S, c = rank_update(Xs, ys, w, block=(bp, bn), interpret=True,
                       use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys, w)
    np.testing.assert_allclose(np.asarray(S, np.float32),
                               np.asarray(S_ref, np.float32),
                               atol=_tol(dtype))
    np.testing.assert_allclose(np.asarray(c, np.float32),
                               np.asarray(c_ref, np.float32),
                               atol=_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 3), n=DIMS_ANY, p=DIMS_ANY, bp=BLOCKS,
       bn=BLOCKS, weighted=st.booleans(), seed=st.integers(0, 3))
def test_rank_update_ragged_shapes_route_to_oracle(m, n, p, bp, bn,
                                                   weighted, seed):
    Xs, ys, w = _rank_case(m, n, p, jnp.float32, seed, weighted)
    S, c = rank_update(Xs, ys, w, block=(bp, bn), interpret=True,
                       use_kernel=True)
    S_ref, c_ref = rank_update_ref(Xs, ys, w)
    tol = 0.0 if is_ragged_samples(n, p) else 1e-5
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=tol)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=tol)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 3), n=DIMS_8, p=DIMS_8, bp=BLOCKS, bn=BLOCKS,
       weighted=st.booleans(), seed=st.integers(0, 3))
def test_rank_update_unfused_matches_oracle(m, n, p, bp, bn, weighted,
                                            seed):
    Xs, ys, w = _rank_case(m, n, p, jnp.float32, seed, weighted)
    S, c = rank_update_unfused(Xs, ys, w, block=(bp, bn), interpret=True)
    S_ref, c_ref = rank_update_ref(Xs, ys, w)
    np.testing.assert_allclose(np.asarray(S), np.asarray(S_ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(c), np.asarray(c_ref), atol=1e-5)
