"""Flash (blockwise) attention vs dense reference: fwd + grads, plus
hypothesis sweeps over shapes/settings. This is the oracle contract for
kernels/flash_attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="optional test dep (pip install .[test])")
from hypothesis import given, settings, strategies as st

from repro.models.attention_core import flash_attention

KEY = jax.random.PRNGKey(0)


def dense_ref(q, k, v, causal=True, window=0, k_valid=None):
    B, S, N, H = q.shape
    K = k.shape[2]
    T = k.shape[1]
    G = N // K
    qg = q.reshape(B, S, K, G, H)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(H)
    i = jnp.arange(S)[:, None]
    j = jnp.arange(T)[None, :]
    m = jnp.ones((S, T), bool)
    if causal:
        m &= j <= i
    if window:
        m &= j > i - window
    if k_valid is not None:
        m &= k_valid[None, :]
    s = jnp.where(m, s, -1e30)
    p = jax.nn.softmax(s, -1)
    p = jnp.where(jnp.any(m, -1, keepdims=True), p, 0)
    return jnp.einsum("bkgst,btkh->bskgh", p, v).reshape(B, S, N, H)


@settings(max_examples=12, deadline=None)
@given(
    s=st.integers(8, 150),
    n=st.sampled_from([2, 4, 8]),
    kv=st.sampled_from([1, 2]),
    h=st.sampled_from([16, 32]),
    causal=st.booleans(),
    window=st.sampled_from([0, 7, 32]),
    block=st.sampled_from([16, 64, 1024]),
)
def test_flash_matches_dense_reference(s, n, kv, h, causal, window, block):
    if n % kv:
        kv = 1
    key1, key2, key3 = jax.random.split(jax.random.PRNGKey(s * 7 + n), 3)
    q = jax.random.normal(key1, (2, s, n, h))
    k = jax.random.normal(key2, (2, s, kv, h))
    v = jax.random.normal(key3, (2, s, kv, h))
    pos = jnp.arange(s)
    out = flash_attention(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                          window=window, block=block)
    ref = dense_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=1e-4)


def test_flash_gradients_match():
    q = jax.random.normal(KEY, (2, 65, 4, 32))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 65, 2, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 65, 2, 32))
    pos = jnp.arange(65)

    def loss_flash(q, k, v):
        return jnp.sum(flash_attention(q, k, v, q_pos=pos, k_pos=pos,
                                       block=16) ** 2)

    def loss_ref(q, k, v):
        return jnp.sum(dense_ref(q, k, v) ** 2)

    gf = jax.grad(loss_flash, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gf, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-4,
                                   err_msg=f"d{name}")


def test_flash_invalid_keys_masked():
    """k_valid=False keys must not contribute."""
    S = 32
    q = jax.random.normal(KEY, (1, S, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 16))
    pos = jnp.arange(S)
    valid = pos < 20
    out = flash_attention(q, k, v, q_pos=pos, k_pos=pos, k_valid=valid,
                          causal=True, block=8)
    # mutate invalid keys: output must not change
    k2 = k.at[:, 20:].set(99.0)
    v2 = v.at[:, 20:].set(-99.0)
    out2 = flash_attention(q, k2, v2, q_pos=pos, k_pos=pos, k_valid=valid,
                           causal=True, block=8)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), atol=1e-6)


def test_flash_fully_masked_rows_are_zero():
    """A query with no visible keys returns 0, not NaN."""
    S = 16
    q = jax.random.normal(KEY, (1, S, 2, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, S, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, S, 2, 16))
    out = flash_attention(q, k, v, q_pos=jnp.arange(S), k_pos=jnp.arange(S),
                          k_valid=jnp.zeros(S, bool), causal=True, block=8)
    assert bool(jnp.all(out == 0))
    assert bool(jnp.all(jnp.isfinite(out)))
