"""Telemetry subsystem tests: the `repro.obs` registry/exporters, and
the instrumentation contract of every subsystem that records into it.

Three layers:

* **Registry/exporter units** — counters, gauges, histograms, spans,
  thread safety, the REPRO_OBS=0 kill switch (in a subprocess, since
  it is read at import), Prometheus text, Chrome trace JSON, the
  snapshot round-trip, and the `python -m repro.obs` CLI.
* **Instrumentation ground truth** — the `dispatch.route` counters must
  agree with the `routes_to_oracle` / `rank_routes_to_oracle`
  predicates over an adversarial shape grid (kernel path, sliver,
  ragged, VMEM-budget bust); engine iteration counters must match
  `return_iters`; autotune cache events must follow the cold/warm/disk
  cycle with one timed candidate per sweep entry.
* **Measured collective bytes** — the obs byte ledger from a real
  8-device ingest probe must equal the arithmetic byte model, and the
  stream demo's Chrome trace must carry the ingest/refit/predict
  lifecycle.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.obs import export as obs_export
from repro.obs.registry import MAX_TRACE_EVENTS, Registry

REPO = Path(__file__).resolve().parents[1]


@pytest.fixture(autouse=True)
def fresh_registry():
    """Every test starts (and leaves) with an empty global registry —
    counters from other test modules must never leak into assertions
    here, and vice versa."""
    obs.reset()
    yield
    obs.reset()


# --- registry units -------------------------------------------------------

def test_counters_labels_and_superset_totals():
    obs.inc("t.calls", kernel="k1", outcome="a")
    obs.inc("t.calls", 2, kernel="k2", outcome="a")
    obs.inc("t.calls", kernel="k1", outcome="b")
    assert obs.counter_total("t.calls") == 4
    assert obs.counter_total("t.calls", kernel="k1") == 2
    assert obs.counter_total("t.calls", kernel="k1", outcome="a") == 1
    assert obs.counter_total("t.calls", kernel="nope") == 0


def test_gauges_and_histograms():
    obs.set_gauge("t.gauge", 1.0, shard="x")
    obs.set_gauge("t.gauge", 7.5, shard="x")     # last write wins
    for v in (1.0, 2.0, 6.0):
        obs.observe("t.lat", v, op="q")
    snap = obs.get_registry().snapshot()
    gauges = {(g["name"], tuple(sorted(g["labels"].items()))): g["value"]
              for g in snap["gauges"]}
    assert gauges[("t.gauge", (("shard", "x"),))] == 7.5
    st = obs.hist_stats("t.lat", op="q")
    assert st["count"] == 3 and st["sum"] == 9.0
    assert st["min"] == 1.0 and st["max"] == 6.0 and st["mean"] == 3.0
    assert obs.hist_stats("t.lat", op="missing") is None


def test_span_records_histogram_and_trace_event():
    with obs.span("t.step", phase="ingest"):
        pass
    st = obs.hist_stats("t.step.ms", phase="ingest")
    assert st is not None and st["count"] == 1 and st["max"] >= 0
    events = obs.get_registry().trace_events()
    assert len(events) == 1
    e = events[0]
    assert e["name"] == "t.step" and e["ph"] == "X" and e["cat"] == "repro"
    assert e["dur"] >= 0 and e["args"] == {"phase": "ingest"}


def test_disabled_registry_is_inert():
    reg = Registry(enabled=False)
    reg.inc("t.calls")
    reg.observe("t.lat", 1.0)
    reg.set_gauge("t.gauge", 1.0)
    with reg.span("t.step"):
        pass
    snap = reg.snapshot()
    assert snap["enabled"] is False
    assert snap["counters"] == [] and snap["histograms"] == []
    assert snap["gauges"] == [] and reg.trace_events() == []


def test_trace_event_cap_drops_and_counts():
    reg = Registry()
    for i in range(MAX_TRACE_EVENTS + 5):
        reg.event("t.e", float(i), 1.0)
    assert len(reg.trace_events()) == MAX_TRACE_EVENTS
    assert reg.snapshot()["dropped_trace_events"] == 5


def test_thread_safety_of_counters():
    n_threads, n_incs = 8, 2500

    def worker():
        for _ in range(n_incs):
            obs.inc("t.parallel", worker="w")

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert obs.counter_total("t.parallel") == n_threads * n_incs


def test_repro_obs_env_kill_switch():
    """REPRO_OBS=0 hard-disables at import; checked in a subprocess
    because the flag is read when `repro.obs` first loads."""
    code = (
        "from repro import obs\n"
        "obs.inc('x.calls')\n"
        "with obs.span('x.step'):\n"
        "    pass\n"
        "assert not obs.enabled()\n"
        "snap = obs.get_registry().snapshot()\n"
        "assert snap['enabled'] is False\n"
        "assert snap['counters'] == [] and snap['histograms'] == []\n"
        "print('DISABLED_OK')\n"
    )
    env = dict(os.environ, REPRO_OBS="0")
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "DISABLED_OK" in r.stdout


# --- exporters ------------------------------------------------------------

def test_prometheus_text_format():
    obs.inc("t.calls", 3, kernel="k")
    obs.observe("t.lat", 2.0)
    text = obs_export.to_prometheus(obs_export.snapshot())
    assert 'repro_t_calls_total{kernel="k"} 3' in text
    assert "repro_t_lat_count 1" in text
    assert "repro_t_lat_sum 2.0" in text


def test_snapshot_write_load_roundtrip(tmp_path):
    obs.inc("t.calls", kernel="k")
    path = tmp_path / "deep" / "snap.json"     # exporter makedirs
    written = obs_export.write_snapshot(str(path), meta={"backend": "cpu"})
    loaded = obs_export.load_snapshot(str(path))
    assert loaded == json.loads(json.dumps(written))
    assert loaded["meta"]["backend"] == "cpu"
    assert loaded["counters"][0]["name"] == "t.calls"


def test_chrome_trace_roundtrip(tmp_path):
    with obs.span("t.step", op="x"):
        pass
    path = tmp_path / "trace.json"
    obs_export.write_chrome_trace(str(path))
    trace = json.loads(path.read_text())
    assert trace["displayTimeUnit"] == "ms"
    (e,) = trace["traceEvents"]
    assert e["name"] == "t.step" and e["ph"] == "X"
    assert set(e) >= {"ts", "dur", "pid", "tid", "args"}


def test_cli_summary_and_prometheus(tmp_path):
    obs.inc("cli.calls", 5, kernel="k")
    path = tmp_path / "snap.json"
    obs_export.write_snapshot(str(path), meta={"backend": "cpu"})
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m", "repro.obs", str(path)],
                       env=env, capture_output=True, text=True, timeout=120)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "cli.calls" in r.stdout and "backend: cpu" in r.stdout
    r2 = subprocess.run(
        [sys.executable, "-m", "repro.obs", "--prometheus", str(path)],
        env=env, capture_output=True, text=True, timeout=120)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert 'repro_cli_calls_total{kernel="k"} 5' in r2.stdout


# --- dispatcher routing counters vs predicate ground truth ----------------

# (m, n, p, expected outcome, expected reason) — the adversarial grid:
# aligned kernel shapes (including the feature-tiled p = 8192 slab),
# the n = 1016 = 8*127 sliver trap, a ragged batch, and the p = 16384
# accumulator-busts-VMEM regime.
LOGISTIC_ROUTE_CASES = (
    (2, 128, 256, "kernel", "kernel"),
    (1, 8, 8192, "kernel", "kernel"),
    (2, 1016, 128, "oracle", "sliver"),
    (2, 100, 64, "oracle", "ragged"),
    (1, 8, 16384, "oracle", "vmem_budget"),
)


@pytest.mark.parametrize("m,n,p,outcome,reason", LOGISTIC_ROUTE_CASES)
def test_logistic_route_counters_match_predicate(m, n, p, outcome, reason):
    from repro.kernels.logistic_grad.ops import (
        logistic_grad, routes_to_oracle,
    )
    assert routes_to_oracle(n, p) == (outcome == "oracle")
    Xs = jnp.ones((m, n, p), jnp.float32)
    ys = jnp.ones((m, n), jnp.float32)
    B = jnp.zeros((m, p), jnp.float32)
    out = logistic_grad(Xs, ys, B, interpret=True)
    assert out.shape == (m, p)
    assert obs.counter_total("dispatch.route", kernel="logistic_grad",
                             outcome=outcome) == 1
    assert obs.counter_total("dispatch.route", kernel="logistic_grad",
                             outcome=outcome, reason=reason) == 1
    other = "oracle" if outcome == "kernel" else "kernel"
    assert obs.counter_total("dispatch.route", kernel="logistic_grad",
                             outcome=other) == 0


RANK_ROUTE_CASES = (
    (2, 128, 64, 128, "kernel", "kernel"),
    (2, 1016, 64, 128, "oracle", "sliver"),
    (2, 100, 64, 128, "oracle", "ragged"),
    (1, 256, 2048, (2048, 256), "oracle", "vmem_budget"),
)


@pytest.mark.parametrize("m,n,p,block,outcome,reason", RANK_ROUTE_CASES)
def test_rank_route_counters_match_predicate(m, n, p, block, outcome,
                                             reason):
    from repro.kernels.rank_update.ops import (
        rank_routes_to_oracle, rank_update,
    )
    assert rank_routes_to_oracle(n, p, block) == (outcome == "oracle")
    Xs = jnp.ones((m, n, p), jnp.float32)
    ys = jnp.ones((m, n), jnp.float32)
    Sig, c = rank_update(Xs, ys, block=block, use_kernel=True,
                         interpret=True)
    assert Sig.shape == (m, p, p) and c.shape == (m, p)
    assert obs.counter_total("dispatch.route", kernel="rank_update",
                             outcome=outcome, reason=reason) == 1


def test_rank_backend_routing_labeled_distinctly():
    """use_kernel=False on a kernel-eligible shape is an oracle route
    for a BACKEND reason, not a shape reason — the counters must keep
    that distinction or the route mix on CPU reads as a kernel bug."""
    from repro.kernels.rank_update.ops import rank_update
    Xs = jnp.ones((2, 128, 64), jnp.float32)
    ys = jnp.ones((2, 128), jnp.float32)
    rank_update(Xs, ys, use_kernel=False)
    assert obs.counter_total("dispatch.route", kernel="rank_update",
                             outcome="oracle", reason="backend") == 1


# --- engine iteration accounting ------------------------------------------

def _toy_lasso(m=2, p=8, n=64):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (m, n, p), jnp.float32)
    Sigmas = jnp.einsum("tnp,tnq->tpq", A, A) / n \
        + 0.5 * jnp.eye(p, dtype=jnp.float32)
    cs = jnp.mean(A, axis=1)
    return Sigmas, cs


def test_engine_iteration_counters_match_return_iters():
    from repro.core.engine import solve_lasso_batched
    Sigmas, cs = _toy_lasso()
    out, n_iters = solve_lasso_batched(Sigmas, cs, 0.1, iters=400,
                                       tol=1e-6, return_iters=True)
    used = int(n_iters)
    assert 0 < used < 400                      # tol fired before ceiling
    assert obs.counter_total("engine.solve.calls", kind="lasso") == 1
    assert obs.counter_total("engine.solve.early_exit", kind="lasso") == 1
    st = obs.hist_stats("engine.solve.iters_used", kind="lasso")
    assert st["count"] == 1 and st["max"] == used
    st_ceiling = obs.hist_stats("engine.solve.iters_ceiling", kind="lasso")
    assert st_ceiling["max"] == 400


def test_engine_records_nothing_under_external_jit():
    """A caller that jits the public wrapper must not crash on the
    recording path, and must record nothing (the counters would
    otherwise tally compilations, not solves)."""
    from repro.core.engine import solve_lasso_batched
    Sigmas, cs = _toy_lasso()

    @jax.jit
    def run(S, c):
        return solve_lasso_batched(S, c, 0.1, iters=50)

    jax.block_until_ready(run(Sigmas, cs))
    assert obs.counter_total("engine.solve.calls") == 0
    assert obs.hist_stats("engine.solve.iters_used") is None


# --- autotune cache events ------------------------------------------------

def test_autotune_cache_event_cycle(tmp_path, monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    timed = []

    def fake_time(fn, reps):
        timed.append(fn)
        return float(len(timed))               # first candidate wins

    monkeypatch.setattr(autotune, "_time_candidate", fake_time)
    n_cands = len(autotune.block_candidates(64, 1))

    cold = autotune.autotune_block(2, 64, 1, backend="cpu",
                                   interpret=True, reps=1)
    assert obs.counter_total("autotune.cache", kernel="fista_step",
                             event="miss_sweep") == 1
    assert len(timed) == n_cands               # every candidate timed once
    st = obs.hist_stats("autotune.candidate_us", kernel="fista_step")
    assert st["count"] == n_cands
    assert obs.hist_stats("autotune.sweep.ms", kernel="fista_step") \
        is not None

    warm = autotune.autotune_block(2, 64, 1, backend="cpu",
                                   interpret=True, reps=1)
    assert obs.counter_total("autotune.cache", kernel="fista_step",
                             event="hit_memory") == 1
    autotune.clear_memory_cache()
    disk = autotune.autotune_block(2, 64, 1, backend="cpu",
                                   interpret=True, reps=1)
    assert obs.counter_total("autotune.cache", kernel="fista_step",
                             event="hit_disk") == 1
    assert len(timed) == n_cands               # hits never re-time
    assert cold == warm == disk
    autotune.clear_memory_cache()


# --- measured collective bytes (8-device probe) ---------------------------

def test_measured_psum_bytes_match_model():
    """The obs byte ledger from one real sharded ingest must equal the
    arithmetic model: 2 traced psum_stats (Sigma and c), each counted
    at local nbytes × data-axis size. For the default (m=8, n=64,
    p=200) probe on a data=4 x task=2 mesh that is
    4 * (4*200*200*4 + 4*200*4) = 2,572,800 bytes."""
    sys.path.insert(0, os.path.join(str(REPO), "benchmarks"))
    from communication import measured_collective_bytes
    rec = measured_collective_bytes()
    assert rec["probe_ok"], rec
    assert rec["psum_calls"] == 2
    assert rec["expected_bytes"] == 2_572_800
    assert rec["psum_bytes"] == rec["expected_bytes"]
    assert rec["matches_model"]


# --- stream service timeline ----------------------------------------------

def test_stream_online_chrome_trace_lifecycle(tmp_path):
    """`stream_online --smoke --obs-out` must produce a valid Chrome
    trace-event JSON whose timeline carries the full service lifecycle
    (ingest, refit, predict spans), plus telemetry-derived headline
    metrics consistent with the run."""
    from examples.stream_online import main as stream_main
    out = tmp_path / "obs.json"
    met = stream_main(["--smoke", "--obs-out", str(out)])
    trace = json.loads((tmp_path / "obs.trace.json").read_text())
    assert trace["displayTimeUnit"] == "ms"
    names = {e["name"] for e in trace["traceEvents"]}
    assert {"stream.ingest", "stream.refit", "stream.predict"} <= names
    for e in trace["traceEvents"]:
        assert e["ph"] == "X" and e["dur"] >= 0 and e["ts"] >= 0
    snap = json.loads(out.read_text())
    assert snap["meta"]["example"] == "stream_online"
    # smoke run: 8 chunks ingested, 4 stream refits + 1 final
    assert obs.counter_total("stream.ingest.chunks") == 8
    assert met["obs_refits_recorded"] == met["refits_during_stream"] + 1
    assert met["obs_ingest_rows_per_s"] > 0
    assert met["obs_refit_latency_ms"] > 0
