"""Distributed DSML (shard_map) tests.

The sharded implementation must (a) produce numerically identical results
to the single-host reference and (b) communicate exactly one all-gather
(the paper's one-round guarantee). Multi-device runs use a subprocess so
the main test session keeps its single-CPU jax runtime.
"""
import os
import re
import sys

import jax
import numpy as np
import pytest

from repro.core import dsml_fit, dsml_fit_sharded, gen_regression
from repro.substrate import REPO_ROOT as REPO, run_probe


def test_sharded_matches_reference_single_device():
    """shard_map over a 1-device mesh must equal the vmap reference."""
    mesh = jax.make_mesh((1,), ("task",))
    data = gen_regression(jax.random.PRNGKey(0), m=4, n=60, p=100, s=5)
    lam, mu, Lam = 0.4, 0.2, 1.0
    ref = dsml_fit(data.Xs, data.ys, lam, mu, Lam,
                   lasso_iters=200, debias_iters=200)
    shd = dsml_fit_sharded(data.Xs, data.ys, lam, mu, Lam, mesh,
                           lasso_iters=200, debias_iters=200)
    np.testing.assert_allclose(np.asarray(ref.beta_tilde),
                               np.asarray(shd.beta_tilde), atol=1e-5)
    np.testing.assert_array_equal(np.asarray(ref.support),
                                  np.asarray(shd.support))


_MULTIDEV = r"""
import jax, numpy as np
from repro.core import dsml_fit, dsml_fit_sharded, gen_regression
from repro.substrate import task_mesh

mesh = task_mesh(8)
data = gen_regression(jax.random.PRNGKey(1), m=8, n=60, p=100, s=5)
lam, mu, Lam = 0.4, 0.2, 1.0
ref = dsml_fit(data.Xs, data.ys, lam, mu, Lam, lasso_iters=200,
               debias_iters=200)
shd = dsml_fit_sharded(data.Xs, data.ys, lam, mu, Lam, mesh,
                       lasso_iters=200, debias_iters=200)
err = float(np.max(np.abs(np.asarray(ref.beta_tilde) -
                          np.asarray(shd.beta_tilde))))
sup_eq = bool(np.all(np.asarray(ref.support) == np.asarray(shd.support)))
print(f"RESULT err={err} sup_eq={sup_eq}")
"""


def test_sharded_matches_reference_eight_devices():
    res = run_probe(_MULTIDEV, n_devices=8, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    m = re.search(r"RESULT err=([\d.e+-]+) sup_eq=(\w+)", res.stdout)
    assert m, res.stdout
    assert float(m.group(1)) < 1e-5
    assert m.group(2) == "True"


def test_one_round_communication_property():
    """The sharded DSML HLO contains exactly one all-gather collective."""
    sys.path.insert(0, os.path.join(REPO, "benchmarks"))
    from communication import verify_one_round
    probe = verify_one_round()
    assert probe["probe_ok"]
    assert probe["one_round"], probe
