"""Per-architecture smoke tests: reduced same-family variant, one forward
and one train step on CPU, asserting output shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config, smoke
from repro.models import Batch, forward_train, init_params
from repro.training.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg):
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = 0.01 * jax.random.normal(KEY, (B, cfg.n_frontend_tokens,
                                            cfg.d_model))
    return Batch(tokens=tokens, labels=tokens, frontend=fe)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    cfg = smoke(get_config(arch))
    params = init_params(KEY, cfg)
    batch = _batch(cfg)
    logits, aux = forward_train(params, cfg, batch, remat=False)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = smoke(get_config(arch))
    state = init_train_state(KEY, cfg)
    step = make_train_step(cfg, peak_lr=1e-3, remat=True)
    batch = _batch(cfg)
    state2, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert float(metrics["grad_norm"]) > 0.0
    assert int(state2.step) == 1
    # params actually moved
    moved = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.any(a != b), state.params, state2.params))
    assert any(bool(x) for x in moved)


def test_loss_decreases_tiny_dense():
    """A few steps on a tiny dense model must reduce loss on a fixed batch."""
    cfg = smoke(get_config("granite-3-2b")).replace(
        compute_dtype="float32", param_dtype="float32")
    state = init_train_state(KEY, cfg)
    step = jax.jit(make_train_step(cfg, peak_lr=3e-3, warmup=1,
                                   total_steps=100))
    batch = _batch(cfg)
    losses = []
    for _ in range(8):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_microbatched_grads_match_full_batch():
    """Gradient accumulation must reproduce the full-batch step."""
    cfg = smoke(get_config("granite-3-2b")).replace(
        compute_dtype="float32", param_dtype="float32")
    state = init_train_state(KEY, cfg)
    batch = _batch(cfg)
    s1, m1 = jax.jit(make_train_step(cfg, microbatches=1))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, microbatches=2))(state, batch)
    diffs = jax.tree.leaves(jax.tree.map(
        lambda a, b: jnp.max(jnp.abs(a - b)), s1.params, s2.params))
    assert max(float(d) for d in diffs) < 5e-5
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
