"""Engine v2 tests: fused-momentum FISTA kernel, convergence-aware
early exit, the batched logistic solver path, and block-size autotuning.

Contracts (ISSUE 3 / DESIGN.md §10):
  * the fused-momentum kernel reproduces the historical two-op
    (kernel step + separate jnp momentum) iterates bitwise in
    interpret mode, and the engine's CPU oracle path reproduces the
    historical ref-step loop bitwise;
  * `tol=` early exit stops before the iteration ceiling and matches
    the full-budget solution to 1e-5;
  * `solve_logistic_lasso_batched` matches the per-task FISTA loops it
    replaced to 1e-5 for k ∈ {1, 3, 8} tasks, and every logistic
    entry point (dsml_logistic_fit, group/icap, masked refit) matches
    its historical per-task implementation;
  * the autotune cache round-trips (second lookup never re-times) and
    explicit `block=` bypasses it entirely.
"""
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    dsml_logistic_fit, gen_classification, gen_regression,
    group_logistic_lasso, icap_logistic, logistic_lasso,
    refit_logistic_masked, solve_lasso_batched,
    solve_logistic_lasso_batched, sufficient_stats,
)
from repro.core.prox import group_soft_threshold, prox_linf, soft_threshold
from repro.core.solvers import fista, power_iteration
from repro.kernels.ista_step.ops import ista_step_batched
from repro.kernels.ista_step.ref import ista_step_batched_ref

KEY = jax.random.PRNGKey(0)


def _quad_batch(m=4, p=32, seed=0):
    A = jax.random.normal(jax.random.PRNGKey(seed), (m, p, p))
    Sigmas = jnp.einsum("tij,tkj->tik", A, A) / p
    cs = jax.random.normal(jax.random.PRNGKey(seed + 1), (m, p))
    return Sigmas, cs


def _reg_stats(m=4, p=32, seed=0):
    """Well-conditioned statistics (n > p regression data) where lasso
    solutions are O(1) — the right scale for 1e-5 comparisons."""
    data = gen_regression(jax.random.PRNGKey(seed), m=m, n=4 * p, p=p, s=5)
    return sufficient_stats(data.Xs, data.ys)


# ---------------------------------------------------------------------------
# historical per-task logistic implementations (the pre-engine-v2 code,
# kept here as the reference the batched path must reproduce)
# ---------------------------------------------------------------------------

def _old_logistic_lasso(X, y, lam, iters):
    n = X.shape[0]
    Sigma = (X.T @ X) / n
    L = 0.25 * power_iteration(Sigma)
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(b):
        z = X @ b
        return -(X.T @ (y * jax.nn.sigmoid(-y * z))) / n

    prox = lambda v, s: soft_threshold(v, s * lam)
    return fista(grad, prox, jnp.zeros(X.shape[1], X.dtype), step, iters)


def _old_group_logistic(Xs, ys, lam, iters, prox_op):
    m, n, p = Xs.shape
    Sigmas, _ = sufficient_stats(Xs, ys)
    L = 0.25 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):
        z = jnp.einsum("tnp,pt->tn", Xs, B)
        g = -jnp.einsum("tnp,tn->pt", Xs, ys * jax.nn.sigmoid(-ys * z)) / n
        return g / m

    prox = lambda V, s: prox_op(V, s * lam)
    return fista(grad, prox, jnp.zeros((p, m), Xs.dtype), step, iters)


def _old_refit_masked(X, y, support, steps):
    n, p = X.shape
    d = support.astype(X.dtype)
    Sigma = (X.T @ X) / n
    L = 0.25 * power_iteration(Sigma)
    step = 1.0 / jnp.maximum(L, 1e-12)

    def body(_, b):
        z = X @ b
        g = -(X.T @ (y * jax.nn.sigmoid(-y * z))) / n
        return (b - step * g) * d

    return jax.lax.fori_loop(0, steps, body, jnp.zeros(p, X.dtype))


# ---------------------------------------------------------------------------
# fused-momentum step: bitwise vs the historical two-op loop
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("iters", "interpret"))
def _two_op_loop(Sigmas, cs, lam, etas, iters, interpret=False):
    """The pre-v2 solve_lasso_batched body: one ista kernel step plus a
    separate jnp momentum pass per iteration."""
    C = cs[..., None]

    def step(Z):
        if interpret:
            return ista_step_batched(Sigmas, Z, C, etas, lam, block=32,
                                     interpret=True)
        return ista_step_batched_ref(Sigmas, Z, C, etas, lam)

    def body(_, carry):
        x, z, t = carry
        x_next = step(z)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = x_next + ((t - 1.0) / t_next) * (x_next - x)
        return x_next, z_next, t_next

    X0 = jnp.zeros_like(C)
    x, _, _ = jax.lax.fori_loop(0, iters, body,
                                (X0, X0, jnp.array(1.0, C.dtype)))
    return x[..., 0]


def test_fused_momentum_matches_two_op_bitwise_interpret():
    """Fused kernel (interpret mode) == historical kernel + jnp momentum."""
    Sigmas, cs = _quad_batch(m=2, p=32)
    etas = jnp.full((2,), 0.02)
    old = _two_op_loop(Sigmas, cs, 0.1, etas, 40, interpret=True)
    new = solve_lasso_batched(Sigmas, cs, 0.1, iters=40, etas=etas,
                              use_kernel=True, interpret=True, block=32)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_fused_momentum_matches_two_op_bitwise_oracle():
    """Engine CPU fast path == historical ref-step + jnp momentum loop."""
    Sigmas, cs = _quad_batch(m=3, p=48)
    etas = jnp.full((3,), 0.02)
    old = _two_op_loop(Sigmas, cs, 0.2, etas, 60)
    new = solve_lasso_batched(Sigmas, cs, 0.2, iters=60, etas=etas)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


# ---------------------------------------------------------------------------
# convergence-aware early exit
# ---------------------------------------------------------------------------

def test_early_exit_matches_full_iteration_result():
    Sigmas, cs = _reg_stats(m=4, p=32)
    full, n_full = solve_lasso_batched(Sigmas, cs, 0.1, iters=1500,
                                       return_iters=True)
    early, n_early = solve_lasso_batched(Sigmas, cs, 0.1, iters=1500,
                                         tol=1e-7, check_every=50,
                                         return_iters=True)
    assert int(n_full) == 1500
    assert int(n_early) < 1500          # the while_loop actually stopped
    np.testing.assert_allclose(np.asarray(early), np.asarray(full),
                               atol=1e-5)


def test_early_exit_unreachable_tol_runs_full_budget():
    Sigmas, cs = _quad_batch(m=2, p=32)
    out, n = solve_lasso_batched(Sigmas, cs, 0.1, iters=100, tol=0.0,
                                 check_every=25, return_iters=True)
    assert int(n) == 100
    ref = solve_lasso_batched(Sigmas, cs, 0.1, iters=100)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_early_exit_iters_is_exact_ceiling():
    """iters not a multiple of check_every must NOT overshoot: the final
    chunk is truncated, so an unreachable tol reproduces the fixed-budget
    result bitwise."""
    Sigmas, cs = _quad_batch(m=2, p=32)
    out, n = solve_lasso_batched(Sigmas, cs, 0.1, iters=30, tol=0.0,
                                 check_every=25, return_iters=True)
    assert int(n) == 30
    ref = solve_lasso_batched(Sigmas, cs, 0.1, iters=30)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_logistic_early_exit_matches_full():
    data = gen_classification(KEY, m=3, n=100, p=32, s=4)
    full = solve_logistic_lasso_batched(data.Xs, data.ys, 0.05, iters=1200)
    early, n = solve_logistic_lasso_batched(data.Xs, data.ys, 0.05,
                                            iters=1200, tol=1e-7,
                                            check_every=50,
                                            return_iters=True)
    assert int(n) < 1200
    np.testing.assert_allclose(np.asarray(early), np.asarray(full),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# batched logistic solver path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m", [1, 3, 8])
def test_logistic_batched_matches_per_task_loop(m):
    data = gen_classification(jax.random.PRNGKey(m), m=m, n=90, p=40, s=4)
    lam = 0.05
    B = solve_logistic_lasso_batched(data.Xs, data.ys, lam, iters=250)
    B_ref = jax.vmap(lambda X, y: _old_logistic_lasso(X, y, lam, 250))(
        data.Xs, data.ys)
    np.testing.assert_allclose(np.asarray(B), np.asarray(B_ref), atol=1e-5)


def test_logistic_lasso_wrapper_matches_old_path():
    data = gen_classification(KEY, m=1, n=80, p=32, s=3)
    X, y = data.Xs[0], data.ys[0]
    b = logistic_lasso(X, y, 0.1, iters=200)
    b_ref = _old_logistic_lasso(X, y, 0.1, 200)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref), atol=1e-5)


def test_dsml_logistic_fit_matches_per_task_pipeline():
    """Steps 1-2 of the batched classification fit must reproduce the
    per-task lasso -> weighted-Hessian-debias pipeline they replaced."""
    from repro.core.debias import inverse_hessian_m
    data = gen_classification(KEY, m=3, n=100, p=32, s=4)
    lam, mu = 0.05, 0.1
    res = dsml_logistic_fit(data.Xs, data.ys, lam, mu, 0.5,
                            lasso_iters=200, debias_iters=200)
    bl_ref = jax.vmap(lambda X, y: _old_logistic_lasso(X, y, lam, 200))(
        data.Xs, data.ys)
    np.testing.assert_allclose(np.asarray(res.beta_local),
                               np.asarray(bl_ref), atol=1e-5)

    def old_debias(X, y, b):
        n = X.shape[0]
        z = X @ b
        w = jax.nn.sigmoid(z) * jax.nn.sigmoid(-z)
        Sw, _ = sufficient_stats(X[None], y[None], weights=w[None])
        M = inverse_hessian_m(Sw[0], mu, iters=200)
        score = (0.5 * (y + 1.0)) - jax.nn.sigmoid(z)
        return b + (M @ (X.T @ score)) / n

    bu_ref = jax.vmap(old_debias)(data.Xs, data.ys, bl_ref)
    np.testing.assert_allclose(np.asarray(res.beta_u), np.asarray(bu_ref),
                               atol=1e-4)


def test_group_and_icap_logistic_match_old_path():
    data = gen_classification(KEY, m=4, n=80, p=24, s=3)
    lam = 0.02
    Bg = group_logistic_lasso(data.Xs, data.ys, lam, iters=200)
    Bg_ref = _old_group_logistic(data.Xs, data.ys, lam, 200,
                                 group_soft_threshold)
    np.testing.assert_allclose(np.asarray(Bg), np.asarray(Bg_ref),
                               atol=1e-5)
    Bi = icap_logistic(data.Xs, data.ys, lam, iters=200)
    Bi_ref = _old_group_logistic(data.Xs, data.ys, lam, 200, prox_linf)
    np.testing.assert_allclose(np.asarray(Bi), np.asarray(Bi_ref),
                               atol=1e-5)


def test_refit_logistic_masked_matches_old_gd_loop():
    data = gen_classification(KEY, m=1, n=80, p=32, s=4)
    X, y = data.Xs[0], data.ys[0]
    sup = jnp.zeros(32, bool).at[:5].set(True)
    b = refit_logistic_masked(X, y, sup)
    b_ref = _old_refit_masked(X, y, sup, 200)
    np.testing.assert_allclose(np.asarray(b), np.asarray(b_ref), atol=1e-6)
    assert not np.any(np.asarray(b)[5:])      # mask respected


def test_logistic_warm_start_converges_faster():
    data = gen_classification(KEY, m=3, n=100, p=32, s=4)
    lam = 0.05
    B_star = solve_logistic_lasso_batched(data.Xs, data.ys, lam, iters=1500)
    _, n_cold = solve_logistic_lasso_batched(data.Xs, data.ys, lam,
                                             iters=1500, tol=1e-6,
                                             check_every=25,
                                             return_iters=True)
    _, n_warm = solve_logistic_lasso_batched(data.Xs, data.ys, lam,
                                             iters=1500, tol=1e-6,
                                             check_every=25, beta0=B_star,
                                             return_iters=True)
    assert int(n_warm) < int(n_cold)


# ---------------------------------------------------------------------------
# streaming logistic refit
# ---------------------------------------------------------------------------

def test_stream_refit_logistic_warm_generation():
    from repro.stream import init_stream_state, refit_logistic
    data = gen_classification(KEY, m=3, n=120, p=32, s=4)
    lam, mu, Lam = 0.05, 0.1, 0.1
    state0 = init_stream_state(3, 32)
    state1, info1 = refit_logistic(state0, data.Xs, data.ys, lam, mu, Lam,
                                   lasso_iters=400, debias_iters=400)
    assert int(info1.generation) == 1
    assert int(info1.support_size) > 0
    # warm second refit on the same window with a fraction of the budget
    # must land on (numerically) the same model
    state2, info2 = refit_logistic(state1, data.Xs, data.ys, lam, mu, Lam,
                                   lasso_iters=50, debias_iters=50,
                                   warm=True)
    assert int(info2.generation) == 2
    np.testing.assert_allclose(np.asarray(state2.beta_local),
                               np.asarray(state1.beta_local), atol=1e-4)
    assert float(info2.jaccard) == 1.0


# ---------------------------------------------------------------------------
# autotune
# ---------------------------------------------------------------------------

def test_autotune_cache_roundtrip(tmp_path, monkeypatch):
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    timed = []
    orig = autotune._time_candidate
    monkeypatch.setattr(autotune, "_time_candidate",
                        lambda fn, reps: (timed.append(1), orig(fn, reps))[1])
    blk = autotune.autotune_block(2, 32, 1, reps=1)
    assert blk in autotune.block_candidates(32, 1)
    assert len(timed) == len(autotune.block_candidates(32, 1))
    assert autotune.cache_path().exists()

    timed.clear()
    blk2 = autotune.autotune_block(2, 32, 1, reps=1)     # in-process hit
    assert blk2 == blk and not timed
    autotune.clear_memory_cache()                        # "new process"
    blk3 = autotune.autotune_block(2, 32, 1, reps=1)     # disk hit
    assert blk3 == blk and not timed


def test_autotune_keys_namespaced_per_kernel(tmp_path, monkeypatch):
    """ISSUE 4 fix: the three sweep families write per-kernel-namespaced
    keys, so coinciding dimension tuples (e.g. a (m, n, p) logistic key
    vs a (m, p, r) fista key with equal numbers) can never collide."""
    import json
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    autotune.autotune_block(2, 32, 16, reps=1)
    autotune.autotune_logistic_block(2, 32, 16, reps=1)
    autotune.autotune_rank_block(2, 32, 16, reps=1)
    disk = json.loads(autotune.cache_path().read_text())
    assert len(disk) == 3
    prefixes = sorted(k.split("/")[0] for k in disk)
    assert prefixes == ["fista_step", "logistic_grad", "rank_update"]


def test_autotune_migrates_legacy_unnamespaced_cache(tmp_path, monkeypatch):
    """Pre-namespace autotune.json files (fista-only, bare keys) keep
    serving: loads migrate them under fista_step/ and rewrite the file
    — and the migrated entry is served without re-timing."""
    import json
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    autotune.cache_path().parent.mkdir(parents=True, exist_ok=True)
    autotune.cache_path().write_text(
        json.dumps({"cpu_m2_p32_r1_float32": [32, 1, 32]}))
    monkeypatch.setattr(
        autotune, "_time_candidate",
        lambda fn, reps: (_ for _ in ()).throw(
            AssertionError("migrated key must be served, not re-timed")))
    assert autotune.autotune_block(2, 32, 1, reps=1) == (32, 1, 32)
    disk = json.loads(autotune.cache_path().read_text())
    assert disk == {"fista_step/cpu_m2_p32_r1_float32": [32, 1, 32]}


def test_autotune_migrates_legacy_logistic_int_values(tmp_path, monkeypatch):
    """ISSUE 5: pre-feature-tiling logistic winners were a bare int bn
    with an implicit full-lane bp = p. Loads widen them through the
    budgeted resolver ((n, p) read back off the key — full-lane here,
    where it fits; clamped to a servable tiling where it would not),
    rewrite the file once, and serve the migrated winner without
    re-timing."""
    import json
    from repro.kernels import autotune
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    autotune.cache_path().parent.mkdir(parents=True, exist_ok=True)
    autotune.cache_path().write_text(
        json.dumps({"logistic_grad/cpu_m2_n32_p16_float32": 16}))
    monkeypatch.setattr(
        autotune, "_time_candidate",
        lambda fn, reps: (_ for _ in ()).throw(
            AssertionError("migrated key must be served, not re-timed")))
    assert autotune.autotune_logistic_block(2, 32, 16, reps=1) == (16, 16)
    disk = json.loads(autotune.cache_path().read_text())
    assert disk == {"logistic_grad/cpu_m2_n32_p16_float32": [16, 16]}


def test_autotune_logistic_never_sweeps_oracle_routed_shapes(tmp_path,
                                                             monkeypatch):
    """Shapes the dispatcher routes to the oracle return the budgeted
    default untimed — the cache is never polluted with unservable keys.
    Covers both routing clauses: sliver-degraded sample tiles
    (n = 1016 = 8*127) and p past the VMEM budget entirely (the padded
    gradient accumulator alone outgrows it around p ~ 16k)."""
    from repro.kernels import autotune
    from repro.kernels.logistic_grad.ops import (
        resolve_logistic_blocks, routes_to_oracle,
    )
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    autotune.clear_memory_cache()
    monkeypatch.setattr(
        autotune, "_time_candidate",
        lambda fn, reps: (_ for _ in ()).throw(
            AssertionError("oracle-routed shape must not sweep")))
    n_sliver = 8 * 127                      # 1016: sliver-degraded
    got = autotune.autotune_logistic_block(2, n_sliver, 64, reps=1)
    assert got == resolve_logistic_blocks(n_sliver, 64)
    p_huge = 20480                          # over-budget accumulator
    assert routes_to_oracle(32, p_huge)
    got_p = autotune.autotune_logistic_block(2, 32, p_huge, reps=1)
    assert got_p == resolve_logistic_blocks(32, p_huge)
    from repro.kernels.rank_update.ops import resolve_rank_blocks
    got_rank = autotune.autotune_rank_block(2, n_sliver, 64, reps=1)
    assert got_rank == resolve_rank_blocks(n_sliver, 64, 128)
    assert not autotune.cache_path().exists()


def test_explicit_block_bypasses_autotune(monkeypatch):
    from repro.kernels import autotune
    def boom(*a, **k):
        raise AssertionError("explicit block= must not consult autotune")
    monkeypatch.setattr(autotune, "autotune_block", boom)
    Sigmas, cs = _quad_batch(m=2, p=32)
    out = solve_lasso_batched(Sigmas, cs, 0.1, iters=20, use_kernel=True,
                              interpret=True, block=32)
    ref = solve_lasso_batched(Sigmas, cs, 0.1, iters=20)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_autotuned_default_policy_on_kernel_path(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path))
    from repro.kernels import autotune
    autotune.clear_memory_cache()
    Sigmas, cs = _reg_stats(m=2, p=32)
    out = solve_lasso_batched(Sigmas, cs, 0.1, iters=30, use_kernel=True,
                              interpret=True)       # block=None -> autotune
    ref = solve_lasso_batched(Sigmas, cs, 0.1, iters=30)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
    assert autotune.cache_path().exists()
