"""shard_map all-to-all MoE (§Perf H2 iter 3) vs the GSPMD reference.

Runs in a subprocess with 8 host devices (2 data x 4 expert-parallel).
"""
import re

from repro.substrate import run_probe

_PROBE = r"""
import dataclasses, jax, jax.numpy as jnp
from repro.configs import get_config, smoke
from repro.models.moe import init_moe_params, moe_apply
from repro.models.moe_shard_map import moe_apply_a2a
from repro.substrate import data_model_mesh, use_mesh

mesh = data_model_mesh(4)            # 8 host devices -> (2 data, 4 model)
cfg = smoke(get_config("qwen3-moe-30b-a3b")).replace(
    compute_dtype="float32", param_dtype="float32")
cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
p = init_moe_params(jax.random.PRNGKey(0), cfg, jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
ref, _ = moe_apply(p, x, cfg)
with use_mesh(mesh):
    out, _ = jax.jit(lambda p, x: moe_apply_a2a(p, x, cfg, mesh))(p, x)
err = float(jnp.max(jnp.abs(out - ref)))
# communication structure: exactly two all-to-alls, no all-reduce of tokens
hlo = jax.jit(lambda p, x: moe_apply_a2a(p, x, cfg, mesh)).lower(p, x).compile().as_text()
import re as _re
n_a2a = len(_re.findall(r"\ball-to-all\(", hlo))
print(f"RESULT err={err} n_a2a={n_a2a}")
"""


def test_a2a_moe_matches_reference_and_uses_all_to_all():
    res = run_probe(_PROBE, n_devices=8, timeout=900)
    assert res.returncode == 0, res.stderr[-2000:]
    m = re.search(r"RESULT err=([\d.e+-]+) n_a2a=(\d+)", res.stdout)
    assert m, res.stdout
    assert float(m.group(1)) < 1e-5
    assert int(m.group(2)) >= 2          # dispatch + return
