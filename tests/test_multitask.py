"""DSML-as-framework-feature tests: sparse probes on backbone features."""
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.multitask import (
    probe_predict, sparse_probe_fit, synthetic_probe_tasks,
)

KEY = jax.random.PRNGKey(0)


def _setup(arch="granite-3-2b", m=4, n=96, s=6):
    cfg = smoke(get_config(arch)).replace(compute_dtype="float32",
                                          param_dtype="float32")
    params = init_params(KEY, cfg)
    data, support = synthetic_probe_tasks(jax.random.PRNGKey(1), params,
                                          cfg, m=m, n=n, s_active=s)
    return cfg, params, data, support


def test_probe_recovers_active_features():
    cfg, params, data, support = _setup()
    res = sparse_probe_fit(data)
    recovered = jnp.sum(res.support & support)
    assert int(recovered) == int(support.sum())       # all true dims found
    # support must be much sparser than d_model
    assert int(res.support.sum()) < cfg.d_model // 4


def test_probe_predictions_fit():
    cfg, params, data, support = _setup()
    res = sparse_probe_fit(data)
    pred = probe_predict(res, data.features)
    r2 = 1 - float(jnp.var(pred - data.targets) / jnp.var(data.targets))
    assert r2 > 0.8


def test_probe_beats_dense_local_ridge_on_support():
    """Shared-support selection must out-select independent per-task fits."""
    cfg, params, data, support = _setup()
    res = sparse_probe_fit(data)
    # per-task local lasso supports (from the DSML intermediate)
    from repro.core import support_of
    local_sup = support_of(res.beta_local.T, 1e-3)
    from repro.core import hamming
    h_dsml = int(hamming(res.support, support))
    h_local = int(hamming(local_sup, support))
    assert h_dsml <= h_local


def test_probe_works_on_ssm_backbone():
    cfg, params, data, support = _setup(arch="mamba2-1.3b")
    res = sparse_probe_fit(data)
    assert int(jnp.sum(res.support & support)) >= int(support.sum()) - 1
