"""Property tests: streaming statistics are additive under ANY chunking.

Hypothesis draws arbitrary split points of an (X, y) stream; chunked
`ingest` must match the one-shot `sufficient_stats` reduction, and
ingest order must not matter for the merge of disjoint shards.
"""
import pytest

pytest.importorskip("hypothesis", reason="optional test dep (pip install .[test])")
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sufficient_stats
from repro.stream import ingest, init_stream_state, merge

M, N, P = 3, 48, 12
KEY = jax.random.PRNGKey(0)
XS = jax.random.normal(KEY, (M, N, P))
YS = jax.random.normal(jax.random.PRNGKey(1), (M, N))
S_REF, C_REF = sufficient_stats(XS, YS)


def _cuts(points):
    return sorted(set(points))


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=N - 1),
                min_size=0, max_size=6))
def test_ingest_additive_over_any_split(points):
    bounds = [0] + _cuts(points) + [N]
    state = init_stream_state(M, P)
    for lo, hi in zip(bounds, bounds[1:]):
        if hi > lo:
            state = ingest(state, XS[:, lo:hi], YS[:, lo:hi])
    np.testing.assert_allclose(np.asarray(state.Sigmas), np.asarray(S_REF),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(state.cs), np.asarray(C_REF),
                               atol=1e-5)
    assert float(state.counts[0]) == N


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=N - 1))
def test_merge_of_disjoint_shards_is_order_invariant(cut):
    a = ingest(init_stream_state(M, P), XS[:, :cut], YS[:, :cut])
    b = ingest(init_stream_state(M, P), XS[:, cut:], YS[:, cut:])
    ab, ba = merge(a, b), merge(b, a)
    for x, y in ((ab.Sigmas, ba.Sigmas), (ab.cs, ba.cs)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)
    np.testing.assert_allclose(np.asarray(ab.Sigmas), np.asarray(S_REF),
                               atol=1e-5)
