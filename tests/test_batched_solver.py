"""Batched sufficient-statistics engine tests.

Contract: `solve_lasso_batched` solves every task's lasso to KKT
optimality in one fused call; the rewired `dsml_fit` is bitwise-stable
(deterministic, and its step-1 estimates bitwise-equal the per-task
`lasso` path it replaced); the substrate shim resolves a working
`shard_map` on whatever jax is installed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (
    debias_lasso, dsml_fit, gen_regression, lasso, sufficient_stats,
)
from repro.core.engine import (
    inverse_hessian_batched, solve_lasso_batched, solve_lasso_grid,
)
from repro.core.solvers import lasso_stats_step_scale
from repro.kernels.ista_step.ops import ista_step_batched
from repro.kernels.ista_step.ref import ista_step_batched_ref
from repro.substrate import make_mesh, shard_map, task_mesh, use_mesh

KEY = jax.random.PRNGKey(0)


def _stats(m=6, n=80, p=64, s=5, seed=0):
    data = gen_regression(jax.random.PRNGKey(seed), m=m, n=n, p=p, s=s)
    Sigmas, cs = sufficient_stats(data.Xs, data.ys)
    return data, Sigmas, cs


# ---------------------------------------------------------------------------
# engine correctness
# ---------------------------------------------------------------------------

def test_solve_lasso_batched_satisfies_kkt_per_task():
    """Every task of the batch must satisfy its own lasso KKT system:
    |Sigma b - c|_inf <= lam, with equality -lam*sign(b) on the active
    set (the engine's normalized-gradient convention)."""
    _, Sigmas, cs = _stats()
    lam = 0.1
    B = solve_lasso_batched(Sigmas, cs, lam, iters=1500)
    G = jnp.einsum("tij,tj->ti", Sigmas, B) - cs
    assert float(jnp.max(jnp.abs(G))) <= lam * 1.05
    active = jnp.abs(B) > 1e-6
    viol = jnp.where(active, jnp.abs(G + lam * jnp.sign(B)), 0.0)
    assert float(jnp.max(viol)) < 5e-3


def test_solve_lasso_batched_matches_per_task_lasso_bitwise():
    """Batch-of-m engine call == vmap of the batch-1 `lasso` wrapper."""
    data, Sigmas, cs = _stats()
    lam = 0.4
    etas = jax.vmap(lasso_stats_step_scale)(Sigmas)
    B = solve_lasso_batched(Sigmas, cs, 0.5 * lam, iters=300, etas=etas)
    B_ref = jax.vmap(lambda X, y: lasso(X, y, lam, iters=300))(
        data.Xs, data.ys)
    np.testing.assert_array_equal(np.asarray(B), np.asarray(B_ref))


def test_solve_lasso_grid_matches_per_lambda_solves():
    """Per-task lambda weighting makes the grid bitwise-equal to the k
    separate solver runs it replaces — including the unregularized
    lam = 0 endpoint of a regularization path."""
    data, Sigmas, cs = _stats()
    lams = jnp.asarray([0.0, 0.1, 0.3, 0.6])
    etas = jax.vmap(lasso_stats_step_scale)(Sigmas)
    G = solve_lasso_grid(Sigmas, cs, 0.5 * lams, iters=400, etas=etas)
    assert G.shape == (4,) + cs.shape
    assert bool(jnp.all(jnp.isfinite(G)))
    for i, lam in enumerate(np.asarray(lams)):
        ref = jax.vmap(lambda X, y: lasso(X, y, float(lam), iters=400))(
            data.Xs, data.ys)
        np.testing.assert_array_equal(np.asarray(G[i]), np.asarray(ref))


def test_lasso_probe_sweep_matches_per_task_lasso():
    """The multitask probe sweep must equal vmap-of-`lasso` on the
    standardized features for every lambda in the grid."""
    from repro.multitask.sparse_probe import (
        ProbeData, lasso_probe_sweep, standardize,
    )
    feats = jax.random.normal(KEY, (3, 50, 32))
    coef = jnp.zeros((3, 32)).at[:, :4].set(1.0)
    targets = jnp.einsum("tnd,td->tn", feats, coef)
    lams = [0.05, 0.2]
    B = lasso_probe_sweep(ProbeData(feats, targets), jnp.asarray(lams),
                          iters=300)
    X = standardize(feats)
    for i, lam in enumerate(lams):
        ref = jax.vmap(lambda Xt, y: lasso(Xt, y, lam, iters=300))(
            X, targets)
        np.testing.assert_array_equal(np.asarray(B[i]), np.asarray(ref))


def test_inverse_hessian_batched_multi_rhs_kkt():
    """The m*p-RHS debias solve: every column of every task's C matrix
    must satisfy ||Sigma c - e_j||_inf <= mu (JM feasibility)."""
    _, Sigmas, _ = _stats(m=3, n=120, p=48)
    mu = float(jnp.sqrt(jnp.log(48.0) / 120))
    Ms = inverse_hessian_batched(Sigmas, mu, iters=1200)
    eye = jnp.eye(48)
    R = jnp.einsum("tij,tkj->tki", Sigmas, Ms) - eye[None]
    assert float(jnp.max(jnp.abs(R))) <= mu * 1.02


@pytest.mark.parametrize("m,p,r", [(4, 128, 1), (3, 64, 8), (5, 100, 1)])
def test_ista_step_batched_matches_oracle(m, p, r):
    A = jax.random.normal(KEY, (m, p, p))
    Sigmas = jnp.einsum("tij,tkj->tik", A, A) / p
    betas = jax.random.normal(jax.random.PRNGKey(1), (m, p, r))
    cs = jax.random.normal(jax.random.PRNGKey(2), (m, p, r))
    etas = jnp.linspace(0.01, 0.1, m)
    out = ista_step_batched(Sigmas, betas, cs, etas, 0.2)
    ref = ista_step_batched_ref(Sigmas, betas, cs, etas, 0.2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


# ---------------------------------------------------------------------------
# dsml_fit stability across the engine rewire
# ---------------------------------------------------------------------------

def test_dsml_fit_bitwise_deterministic():
    data, _, _ = _stats()
    r1 = dsml_fit(data.Xs, data.ys, 0.4, 0.2, 1.0,
                  lasso_iters=200, debias_iters=200)
    r2 = dsml_fit(data.Xs, data.ys, 0.4, 0.2, 1.0,
                  lasso_iters=200, debias_iters=200)
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dsml_fit_matches_per_task_pipeline():
    """The batched fit must reproduce the per-task lasso -> debias
    pipeline it replaced: step 1 bitwise, step 2 to float32 roundoff."""
    data, _, _ = _stats()
    lam, mu = 0.4, 0.2
    res = dsml_fit(data.Xs, data.ys, lam, mu, 1.0,
                   lasso_iters=200, debias_iters=200)
    bl = jax.vmap(lambda X, y: lasso(X, y, lam, iters=200))(data.Xs, data.ys)
    np.testing.assert_array_equal(np.asarray(res.beta_local), np.asarray(bl))
    bu = jax.vmap(lambda X, y, b: debias_lasso(X, y, b, mu, iters=200))(
        data.Xs, data.ys, bl)
    np.testing.assert_allclose(np.asarray(res.beta_u), np.asarray(bu),
                               atol=1e-6)


# ---------------------------------------------------------------------------
# substrate shim
# ---------------------------------------------------------------------------

def test_substrate_shard_map_resolves_on_installed_jax():
    """The shim must produce a working shard_map (collective + replicated
    output) regardless of where this jax version keeps the API."""
    mesh = task_mesh(1)
    def worker(x):
        g = jax.lax.all_gather(x, "task", tiled=True)
        return x * 2.0, jnp.sum(g)
    fn = shard_map(worker, mesh=mesh, in_specs=(P("task"),),
                   out_specs=(P("task"), P()))
    doubled, total = jax.jit(fn)(jnp.arange(4.0))
    np.testing.assert_allclose(np.asarray(doubled), [0.0, 2.0, 4.0, 6.0])
    assert float(total) == 6.0


def test_substrate_use_mesh_and_make_mesh():
    mesh = make_mesh((1,), ("task",))
    assert mesh.shape["task"] == 1
    with use_mesh(mesh) as m:
        assert m is mesh
