"""Serving-front correctness: atomic generation swaps, the microbatch
admission layer, the predict input contract, and the substrate feed.

The concurrency claims are tested the only way that means anything —
with real threads hammering predict while ingest/refit adopt new
generations — and verified bitwise: every observed (scores, generation)
pair must reproduce exactly from that generation's recorded model under
the same dispatch shape, so a torn or mixed-generation read cannot hide
inside a tolerance.
"""
import os
import threading
import time
from concurrent.futures import Future

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.obs.registry import Registry, _quantile
from repro.stream import (
    ModelGeneration, ServingFront, StreamingDsmlService, bucket_rows,
    init_stream_state, ingest,
)
from repro.stream.serve import _Request
from repro.stream.service import _predict_shared
from repro.substrate import data_task_mesh, feed_chunk, feed_shards

LAM, MU, THR = 0.05, 0.1, 0.02
M, P, CHUNK = 4, 32, 128


def _service(**kw):
    kw.setdefault("refit_every", CHUNK)
    kw.setdefault("lasso_iters", 150)
    kw.setdefault("debias_iters", 150)
    kw.setdefault("refit_tol", 1e-5)
    kw.setdefault("guard", False)
    return StreamingDsmlService(M, P, lam=LAM, mu=MU, Lam=THR, **kw)


def _chunk(rng, n=CHUNK):
    X = rng.standard_normal((M, n, P)).astype(np.float32)
    w = rng.standard_normal((M, P)).astype(np.float32) / np.sqrt(P)
    y = (np.einsum("tnp,tp->tn", X, w)
         + 0.05 * rng.standard_normal((M, n))).astype(np.float32)
    return jnp.asarray(X), jnp.asarray(y)


def _reference(beta_np, X):
    """The verification oracle: the SAME jitted dispatch at the SAME
    shapes on that generation's recorded weights — bitwise equal to
    what serving must have computed if (and only if) it read one
    coherent snapshot."""
    return np.asarray(_predict_shared(jnp.asarray(beta_np), X))


# -- units ----------------------------------------------------------------

def test_bucket_rows_powers_of_two():
    assert [bucket_rows(r) for r in (1, 7, 8, 9, 63, 64, 65)] == \
        [8, 8, 8, 16, 64, 64, 128]
    assert bucket_rows(3, min_bucket=4) == 4
    with pytest.raises(ValueError):
        bucket_rows(0)


def test_obs_quantiles():
    assert _quantile([5.0], 0.99) == 5.0
    vals = sorted(float(v) for v in range(1, 101))
    assert _quantile(vals, 0.5) == pytest.approx(50.5)
    assert _quantile(vals, 0.99) == pytest.approx(99.01)
    reg = Registry()
    for v in range(1, 101):
        reg.observe("lat.ms", float(v), route="a" if v % 2 else "b")
    q = reg.hist_quantiles("lat.ms")
    assert q[0.5] == pytest.approx(50.5)
    assert q[0.99] == pytest.approx(99.01)
    assert reg.hist_quantiles("lat.ms", route="a")[0.5] == pytest.approx(50.0)
    assert reg.hist_quantiles("missing") is None
    snap = reg.snapshot()
    hist = [h for h in snap["histograms"] if h["labels"] == {"route": "a"}][0]
    assert hist["p50"] == pytest.approx(50.0)
    assert "p99" in hist


def test_disabled_registry_retains_nothing():
    reg = Registry(enabled=False)
    reg.observe("lat.ms", 1.0)
    assert reg.hist_quantiles("lat.ms") is None
    assert reg.snapshot()["histograms"] == []


# -- predict contract -----------------------------------------------------

def test_predict_rank1_is_one_shared_row():
    svc = _service()
    rng = np.random.default_rng(0)
    svc.ingest(*_chunk(rng))
    row = rng.standard_normal(P).astype(np.float32)
    out = svc.predict(row)
    assert out.shape == (M, 1)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(svc.predict(row.reshape(1, P))))


def test_predict_rows_counter_counts_normalized_rows():
    svc = _service()
    rng = np.random.default_rng(1)
    svc.ingest(*_chunk(rng))
    before = obs.counter_total("stream.predict.rows")
    svc.predict(rng.standard_normal(P).astype(np.float32))
    assert obs.counter_total("stream.predict.rows") - before == 1  # not P
    svc.predict(rng.standard_normal((5, P)).astype(np.float32))
    svc.predict(rng.standard_normal((M, 3, P)).astype(np.float32))
    assert obs.counter_total("stream.predict.rows") - before == 1 + 5 + 3


def test_predict_rejects_malformed_inputs():
    svc = _service()
    rng = np.random.default_rng(2)
    for bad in (rng.standard_normal(P + 1),
                rng.standard_normal((5, P + 1)),
                rng.standard_normal((M + 1, 5, P)),
                rng.standard_normal((M, 5, P + 1)),
                rng.standard_normal((2, 2, 2, 2))):
        with pytest.raises(ValueError):
            svc.predict(bad.astype(np.float32))


# -- generation snapshots -------------------------------------------------

def test_snapshot_survives_adoption_and_publish_sites():
    svc = _service()
    rng = np.random.default_rng(3)
    held = svc.serving()
    assert isinstance(held, ModelGeneration) and held.generation == 0
    held_beta = np.asarray(held.beta_tilde)
    svc.ingest(*_chunk(rng))                       # triggers a refit
    assert svc.generation == 1
    assert svc.serving().generation == 1
    # the snapshot captured before adoption is untouched
    assert held.generation == 0
    np.testing.assert_array_equal(np.asarray(held.beta_tilde), held_beta)


def test_restore_republishes(tmp_path):
    svc = _service(ckpt_dir=str(tmp_path))
    rng = np.random.default_rng(4)
    svc.ingest(*_chunk(rng))
    fitted = np.asarray(svc.serving().beta_tilde)
    assert svc.serving().generation == 1
    fresh = _service(ckpt_dir=str(tmp_path))
    assert fresh.serving().generation == 0
    fresh.restore()
    assert fresh.serving().generation == 1
    np.testing.assert_array_equal(np.asarray(fresh.serving().beta_tilde),
                                  fitted)


def test_ingest_while_predict_interleaving_bitwise():
    """Predictions taken between chunk folds must equal post-hoc
    predictions from the same generation's model, bitwise."""
    svc = _service()
    rng = np.random.default_rng(5)
    X0 = jnp.asarray(rng.standard_normal((6, P)).astype(np.float32))
    betas = {0: np.asarray(svc.serving().beta_tilde)}
    observed = []
    for _ in range(6):
        scores, gen = svc.predict(X0, return_generation=True)
        observed.append((np.asarray(scores), gen))
        svc.ingest(*_chunk(rng))
        snap = svc.serving()
        betas[snap.generation] = np.asarray(snap.beta_tilde)
    assert svc.generation >= 3        # refits really happened mid-stream
    for scores, gen in observed:
        np.testing.assert_array_equal(scores, _reference(betas[gen], X0))


def test_threaded_generation_swap_stress():
    """Predict hammered from threads while ingest adopts generation
    after generation: every observed (scores, generation) pair must
    reproduce bitwise from that generation's model — a torn read of a
    half-swapped model cannot produce a score vector that matches any
    single generation. Generations must also be nondecreasing per
    thread (a reader can lag the swap, never un-see it)."""
    svc = _service(max_refit_interval=CHUNK)       # adopt every chunk
    rng = np.random.default_rng(6)
    X0 = jnp.asarray(rng.standard_normal((4, P)).astype(np.float32))
    svc.predict(X0)                                # compile before racing
    betas = {0: np.asarray(svc.serving().beta_tilde)}
    chunks = [_chunk(rng) for _ in range(12)]
    done = threading.Event()
    results, errors = [], []
    lock = threading.Lock()

    def ingest_loop():
        try:
            for X, y in chunks:
                svc.ingest(X, y)
                snap = svc.serving()
                betas[snap.generation] = np.asarray(snap.beta_tilde)
        finally:
            done.set()

    def predict_loop():
        mine = []
        try:
            while not done.is_set():
                scores, gen = svc.predict(X0, return_generation=True)
                mine.append((np.asarray(scores), gen))
        except Exception as e:  # noqa: BLE001 - surfaced to the assert
            errors.append(e)
        with lock:
            results.append(mine)

    workers = [threading.Thread(target=predict_loop) for _ in range(4)]
    for t in workers:
        t.start()
    feeder = threading.Thread(target=ingest_loop)
    feeder.start()
    feeder.join()
    for t in workers:
        t.join()

    assert not errors, errors
    assert svc.generation == len(chunks)
    total = 0
    refs = {}
    for mine in results:
        gens = [g for _, g in mine]
        assert gens == sorted(gens)               # never un-adopts
        for scores, gen in mine:
            assert gen in betas
            if gen not in refs:
                refs[gen] = _reference(betas[gen], X0)
            np.testing.assert_array_equal(scores, refs[gen])
            total += 1
    assert total > 0


# -- the microbatch front -------------------------------------------------

def test_front_process_single_dispatch_parity():
    """_process on hand-built requests (no threads): one padded
    dispatch, per-request slices bitwise equal to scoring the padded
    batch directly, one shared generation stamp."""
    svc = _service()
    rng = np.random.default_rng(7)
    svc.ingest(*_chunk(rng))
    front = ServingFront(svc, max_batch=16)
    rows = [rng.standard_normal((n, P)).astype(np.float32)
            for n in (1, 3, 2)]
    reqs = [_Request(x, Future(), time.perf_counter()) for x in rows]
    front._process(reqs)

    padded = np.zeros((bucket_rows(6), P), np.float32)
    padded[:1], padded[1:4], padded[4:6] = rows[0], rows[1], rows[2]
    snap = svc.serving()
    expect = _reference(np.asarray(snap.beta_tilde), jnp.asarray(padded))
    off = 0
    for req, x in zip(reqs, rows):
        res = req.future.result(timeout=1)
        assert res.generation == snap.generation
        np.testing.assert_array_equal(res.scores,
                                      expect[:, off:off + x.shape[0]])
        off += x.shape[0]


def test_front_threaded_serving_during_ingest():
    """Threaded smoke: submits race a live ingest/refit loop; every
    result's generation is a real published generation and its scores
    match that generation's model (allclose — the padded bucket shape
    varies with batch fill, which legitimately changes reduction
    order)."""
    svc = _service()
    rng = np.random.default_rng(8)
    betas = {0: np.asarray(svc.serving().beta_tilde)}
    chunks = [_chunk(rng) for _ in range(6)]
    row = rng.standard_normal(P).astype(np.float32)
    with ServingFront(svc, max_batch=8, max_delay_ms=1.0) as front:
        front.predict(row, timeout=10)             # compile before racing
        done = threading.Event()

        def ingest_loop():
            try:
                for X, y in chunks:
                    svc.ingest(X, y)
                    snap = svc.serving()
                    betas[snap.generation] = np.asarray(snap.beta_tilde)
            finally:
                done.set()

        feeder = threading.Thread(target=ingest_loop)
        feeder.start()
        futs = []
        while not done.is_set():
            futs.append(front.submit(row))
            time.sleep(0.001)
        feeder.join()
        res = [f.result(timeout=10) for f in futs]

    assert svc.generation >= 3
    for r in res:
        assert r.generation in betas
        want = betas[r.generation] @ row           # (m,) float32 einsum
        np.testing.assert_allclose(r.scores[:, 0], want, atol=1e-4)


def test_front_submit_validation_and_stop():
    svc = _service()
    front = ServingFront(svc, max_batch=4)
    with pytest.raises(RuntimeError):              # not started
        front.submit(np.zeros(P, np.float32))
    front.start()
    with pytest.raises(ValueError):                # wrong feature count
        front.submit(np.zeros(P + 1, np.float32))
    with pytest.raises(ValueError):                # oversized block
        front.submit(np.zeros((5, P), np.float32))
    fut = front.submit(np.zeros(P, np.float32))
    assert fut.result(timeout=10).scores.shape == (M, 1)
    front.stop()
    with pytest.raises(RuntimeError):              # stopped
        front.submit(np.zeros(P, np.float32))


@pytest.mark.serve_perf
@pytest.mark.skipif(not os.environ.get("REPRO_SERVE_PERF"),
                    reason="set REPRO_SERVE_PERF=1 for the latency smoke")
def test_front_p99_latency_smoke():
    """Opt-in latency gate: a loaded front must hold a loose p99 (the
    committed regression floor lives in benchmarks/check_regression.py;
    this is the in-tree canary)."""
    svc = _service()
    rng = np.random.default_rng(9)
    svc.ingest(*_chunk(rng))
    row = rng.standard_normal(P).astype(np.float32)
    with ServingFront(svc, max_batch=32, max_delay_ms=1.0) as front:
        front.predict(row, timeout=10)
        futs = [front.submit(row) for _ in range(400)]
        for f in futs:
            f.result(timeout=30)
        q = front.latency_quantiles()
    assert q is not None and q[0.99] < 250.0, q


# -- the substrate feed ---------------------------------------------------

def test_feed_chunk_matches_host_ingest():
    n_dev = len(jax.devices())
    n_task = 2 if n_dev >= 2 else 1
    n_data = next((d for d in (4, 2, 1)
                   if n_dev // n_task >= d and CHUNK % d == 0), 1)
    mesh = data_task_mesh(n_task=n_task, n_data=n_data)
    rng = np.random.default_rng(10)
    X, y = _chunk(rng)
    host = ingest(init_stream_state(M, P), X, y)
    svc = _service(mesh=mesh)
    svc._interval = 10 ** 9                        # fold only, no refit
    svc.ingest(X, y)
    np.testing.assert_allclose(np.asarray(svc.state.Sigmas),
                               np.asarray(host.Sigmas), atol=1e-4)
    np.testing.assert_allclose(np.asarray(svc.state.cs),
                               np.asarray(host.cs), atol=1e-5)


def test_feed_shards_equals_feed_chunk():
    """The per-worker assembly path must produce the same global array
    (values AND sharding) as the single-controller placement."""
    n_dev = len(jax.devices())
    n_task = 2 if n_dev >= 2 else 1
    n_data = 2 if n_dev >= 4 else 1
    mesh = data_task_mesh(n_task=n_task, n_data=n_data)
    rng = np.random.default_rng(11)
    X, y = _chunk(rng, n=64)
    Xc, yc = feed_chunk(X, y, mesh)
    blocks = np.split(np.asarray(X), n_data, axis=1)
    yblocks = np.split(np.asarray(y), n_data, axis=1)
    Xs, ys = feed_shards(blocks, yblocks, mesh)
    np.testing.assert_array_equal(np.asarray(Xs), np.asarray(Xc))
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(yc))
    assert Xs.sharding.is_equivalent_to(Xc.sharding, X.ndim)
    with pytest.raises(ValueError):                # wrong block count
        feed_shards(blocks[:1] * (n_data + 1), yblocks[:1] * (n_data + 1),
                    mesh)
