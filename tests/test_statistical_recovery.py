"""Statistical correctness tier: the paper's CLAIMS, not code parity.

Everything else in the suite checks that refactored paths reproduce
older paths; nothing pinned down whether the estimator is actually
GOOD. These seeded end-to-end checks assert the two statistical
properties of Wang–Kolar–Srebro (arXiv:1510.00633) — exact shared
support recovery by the one-round group threshold, and
debiased-estimator error within a fixed factor of the centralized
lasso oracle (the one-shot guarantee of Lee et al., arXiv:1503.04337)
— for both the regression (Algorithm 1) and logistic (Section 4)
paths, at the paper's Section-6 data regime (AR(0.5) design, shared
support, p = 200, s = 10, m = 10).

All runs are seeded, so the committed thresholds are deterministic on
a given jax/CPU stack; they carry 25%+ empirical margin (gap between
the weakest on-support and strongest off-support row norm over seeds
0-2) so float-level drift across versions cannot flip them. Runs in
the default `make test` flow and alone via `make test-stats`.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    dsml_fit, dsml_logistic_fit, estimation_error, gen_classification,
    gen_regression, group_lasso, sufficient_stats,
)
from repro.core.engine import solve_lasso_eq2, solve_logistic_lasso_batched

P, S, M = 200, 10, 10          # the paper's Section-6 regime
N_REG, N_LOG = 120, 350        # samples per task (logistic needs more:
                               # each label carries ~1 bit, not a real)
LAM_THRESH = 0.75              # group threshold: inside the on/off-support
                               # row-norm gap for every calibrated seed


def _base_lam(n: int) -> float:
    return float(jnp.sqrt(jnp.log(float(P)) / n))


def _fit_regression(seed: int, n: int = N_REG, Lam: float = LAM_THRESH):
    data = gen_regression(jax.random.PRNGKey(seed), m=M, n=n, p=P, s=S)
    base = _base_lam(n)
    res = dsml_fit(data.Xs, data.ys, 4.0 * base, base, Lam=Lam)
    return data, res


def _fit_logistic(seed: int, n: int = N_LOG, Lam: float = LAM_THRESH):
    data = gen_classification(jax.random.PRNGKey(seed), m=M, n=n, p=P, s=S)
    base = _base_lam(n)
    res = dsml_logistic_fit(data.Xs, data.ys, base, 2.0 * base, Lam=Lam,
                            lasso_iters=400, debias_iters=400)
    return data, res


# ---------------------------------------------------------------------------
# exact support recovery (paper Theorem 1 regime)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1])
def test_regression_support_recovery_exact(seed):
    """One round of debias + group threshold recovers the true shared
    support exactly at the paper regime — AND with a margin: the
    weakest on-support row norm clears the threshold the strongest
    off-support row misses."""
    data, res = _fit_regression(seed)
    np.testing.assert_array_equal(np.asarray(res.support),
                                  np.asarray(data.support))
    norms = jnp.linalg.norm(res.beta_u.T, axis=-1)
    assert float(jnp.min(norms[data.support])) > LAM_THRESH
    assert float(jnp.max(norms[~data.support])) < LAM_THRESH


@pytest.mark.parametrize("seed", [0, 1])
def test_logistic_support_recovery_exact(seed):
    data, res = _fit_logistic(seed)
    np.testing.assert_array_equal(np.asarray(res.support),
                                  np.asarray(data.support))
    norms = jnp.linalg.norm(res.beta_u.T, axis=-1)
    assert float(jnp.min(norms[data.support])) > LAM_THRESH
    assert float(jnp.max(norms[~data.support])) < LAM_THRESH


# ---------------------------------------------------------------------------
# debiased-estimator error vs the centralized lasso oracle
# ---------------------------------------------------------------------------

def test_regression_debiased_error_tracks_centralized_oracle():
    """The one-round estimator must not give up accuracy for its
    communication budget: beta_tilde's L2 error stays within a fixed
    factor of the centralized per-task lasso AND the centralized group
    lasso, each solved on all the data at the theory lambda.
    (Empirically DSML beats both here — factor 1.0 with ~2.5x margin.)
    """
    data, res = _fit_regression(0)
    err_dsml = float(estimation_error(res.beta_tilde.T, data.B))
    Sigmas, cs = sufficient_stats(data.Xs, data.ys)
    B_lasso = solve_lasso_eq2(Sigmas, cs, 4.0 * _base_lam(N_REG)).T
    err_lasso = float(estimation_error(B_lasso, data.B))
    B_group = group_lasso(data.Xs, data.ys, 2.0 * _base_lam(N_REG))
    err_group = float(estimation_error(B_group, data.B))
    assert err_dsml <= 1.0 * err_lasso, (err_dsml, err_lasso)
    assert err_dsml <= 1.0 * err_group, (err_dsml, err_group)


def test_logistic_debiased_error_tracks_centralized_oracle():
    data, res = _fit_logistic(0)
    err_dsml = float(estimation_error(res.beta_tilde.T, data.B))
    B_lasso = solve_logistic_lasso_batched(data.Xs, data.ys,
                                           _base_lam(N_LOG), iters=400).T
    err_lasso = float(estimation_error(B_lasso, data.B))
    assert err_dsml <= 1.0 * err_lasso, (err_dsml, err_lasso)


# ---------------------------------------------------------------------------
# rate sanity: more data per task must shrink the error
# ---------------------------------------------------------------------------

def test_regression_error_scales_down_with_n():
    """4x the samples must at least halve the thresholded-debiased
    error (the sqrt(s log p / n) rate predicts exactly 2x)."""
    data_small, res_small = _fit_regression(0, n=60)
    err_small = float(estimation_error(res_small.beta_tilde.T,
                                       data_small.B))
    data_big, res_big = _fit_regression(0, n=240)
    err_big = float(estimation_error(res_big.beta_tilde.T, data_big.B))
    assert err_big < 0.5 * err_small, (err_big, err_small)
