"""Large-p logistic sweep: the paper's p >> n regime on the kernel path.

Wang, Kolar & Srebro's whole setting is high-dimensional linear
predictors with p far beyond the per-task sample budget, yet until the
feature-tiled slabs (DESIGN.md §12) every p > 4096 silently fell off
the fused-kernel fast path onto the jnp oracle. This driver sweeps the
batched l1-logistic solve across p up to 8192 — past the old full-lane
cliff — and, at each point, runs the SAME reduced-budget solve twice:
once on the engine's XLA oracle path and once with the feature-tiled
pallas kernel forced on (`use_kernel=True`, interpret mode off-TPU),
so the sweep proves both the statistics (support recovery at p >> n)
and the routing (kernel iterates == oracle iterates).

fig1-style contract: `main()` returns printable ``name,us,k=v`` rows,
persists a JSON artifact, and the statistical tier drives one point
through it with committed golden bands
(tests/test_figures_smoke.py::test_largep_logistic_smoke_golden_metrics).

    python benchmarks/largep_logistic.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.core import hamming, support_of
from repro.core.engine import solve_logistic_lasso_batched
from repro.core.synth import sample_coefficients
from repro.kernels.logistic_grad.ops import (
    resolve_logistic_blocks, routes_to_oracle,
)

VARY_P = (2048, 8192)
SMOKE_P = (8192,)


def gen_largep_classification(key, *, m: int, n: int, p: int, s: int,
                              signal_scale: float = 4.0):
    """Identity-covariance logistic data for the p >> n sweep — the
    AR-covariance generator of `core/synth` materializes a (p, p)
    cholesky, which at p = 8192 is 256 MB of setup the sweep does not
    need; isotropic rows keep the point generation O(m n p)."""
    k_b, k_x, k_y = jax.random.split(key, 3)
    B, support = sample_coefficients(k_b, p, m, s, 2.0, signal_scale)
    Xs = jax.random.normal(k_x, (m, n, p))
    logits = jnp.einsum("tnp,pt->tn", Xs, B)
    u = jax.random.uniform(k_y, (m, n))
    ys = jnp.where(u < jax.nn.sigmoid(logits), 1.0, -1.0)
    return Xs, ys, B, support


@jax.jit
def _logistic_etas(Xs, iters: int = 50):
    """Per-task 1 / max(lambda_max(Sigma)/4, eps) step sizes WITHOUT
    materializing Sigma — the engine's default etas build the (m, p, p)
    covariance stack, which at p = 8192 is a gigabyte of scratch this
    sweep exists to avoid. Power iteration on v -> X'(Xv)/n instead."""
    m, n, p = Xs.shape
    v = jnp.ones((m, p), Xs.dtype) / jnp.sqrt(float(p))

    def body(_, v):
        w = jnp.einsum("tnp,tn->tp", Xs, jnp.einsum("tnp,tp->tn", Xs, v)) / n
        return w / jnp.maximum(jnp.linalg.norm(w, axis=-1, keepdims=True),
                               1e-12)

    v = jax.lax.fori_loop(0, iters, body, v)
    w = jnp.einsum("tnp,tp->tn", Xs, v)
    lmax = jnp.einsum("tn,tn->t", w, w) / n
    return 1.0 / jnp.maximum(0.25 * lmax, 1e-12)


def eval_point(key, *, p: int, m: int = 4, n: int = 256, s: int = 5,
               iters: int = 150, kernel_iters: int = 20) -> dict:
    """One sweep point: full-budget oracle solve for the recovery
    metrics, plus a matched reduced-budget kernel-vs-oracle pair for
    the routing proof (interpret-mode emulation is too slow to run the
    full budget on CPU; on TPU the kernel IS the default path)."""
    Xs, ys, B, support = gen_largep_classification(key, m=m, n=n, p=p, s=s)
    lam = 0.5 * float(jnp.sqrt(jnp.log(float(p)) / n))
    etas = _logistic_etas(Xs)
    t0 = time.perf_counter()
    B_hat = solve_logistic_lasso_batched(Xs, ys, lam, iters=iters, etas=etas)
    B_hat.block_until_ready()
    solve_s = time.perf_counter() - t0

    # pin the budgeted default tiling explicitly: block=None on the
    # kernel path would trigger the autotune sweep, and timing dozens of
    # interpret-mode candidates at p = 8192 is minutes of emulation this
    # sweep point does not want to measure
    blocks = resolve_logistic_blocks(n, p)
    ki = min(iters, kernel_iters)
    B_kern = solve_logistic_lasso_batched(Xs, ys, lam, iters=ki,
                                          etas=etas, use_kernel=True,
                                          block=blocks)
    B_orcl = solve_logistic_lasso_batched(Xs, ys, lam, iters=ki,
                                          etas=etas, use_kernel=False)
    kernel_dev = float(jnp.max(jnp.abs(B_kern - B_orcl)))

    sup_hat = support_of(B_hat.T, 1e-3)
    bn, bp = blocks
    return {
        "hamming": int(hamming(sup_hat, support)),
        "est_err": float(jnp.linalg.norm(B_hat - B.T)),
        "kernel_dev": kernel_dev,
        "routed_oracle": bool(routes_to_oracle(n, p)),
        "bn": bn, "bp": bp, "solve_s": solve_s,
    }


def sweep(p_points=VARY_P, *, m: int = 4, n: int = 256, s: int = 5,
          iters: int = 150, kernel_iters: int = 20, seed: int = 0):
    return {p: eval_point(jax.random.PRNGKey(seed), p=p, m=m, n=n, s=s,
                          iters=iters, kernel_iters=kernel_iters)
            for p in p_points}


def main(p_points=VARY_P, out_dir: str = "experiments/paper", *,
         m: int = 4, n: int = 256, s: int = 5, iters: int = 150,
         kernel_iters: int = 20):
    results = sweep(p_points, m=m, n=n, s=s, iters=iters,
                    kernel_iters=kernel_iters)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "largep_logistic.json"), "w") as f:
        json.dump({str(p): v for p, v in results.items()}, f, indent=2)
    rows = []
    for p, met in results.items():
        rows.append(
            f"largep_logistic_p{p}_n{n}_m{m},{met['solve_s'] * 1e6:.0f},"
            f"hamming={met['hamming']};est={met['est_err']:.2f};"
            f"kernel_dev={met['kernel_dev']:.2e};"
            f"routed_oracle={int(met['routed_oracle'])};"
            f"bn={met['bn']};bp={met['bp']}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="one large-p point with a reduced budget")
    args = ap.parse_args()
    pts = SMOKE_P if args.smoke else VARY_P
    for r in main(pts, iters=100 if args.smoke else 150):
        print(r)
