"""Paper Table 1, communication column: measured bytes per worker.

  local lasso        0                   (no communication)
  group lasso        O(np)  per worker   (centralizing the raw data)
  DSML               O(p)   per worker   (ONE debiased p-vector up,
                                          p-bit support mask down)

Bytes are measured from the actual arrays the implementation ships, and
the DSML one-round property is verified structurally: the SPMD HLO of
`dsml_fit_sharded` contains exactly ONE all-gather collective.

Since PR 7 the streaming-ingest column is MEASURED, not modeled: the
`repro.obs` collective counters (fed by every `substrate/collectives`
helper at trace time — local-shard nbytes × mesh participants) are read
back from an 8-device probe subprocess, and cross-checked against the
arithmetic model so the two can never silently diverge.
"""
from __future__ import annotations

import json
import os
import re
import time

from repro.substrate import run_probe


def measured_bytes(m: int = 10, n: int = 50, p: int = 200) -> dict:
    f32 = 4
    return {
        "lasso": 0,
        "group_lasso_centralized": m * n * p * f32 + m * n * f32,  # X_t, y_t
        "dsml_up": m * p * f32,                # debiased vectors to master
        "dsml_down": m * p // 8,               # support bitmask broadcast
        "dsml_total": m * p * f32 + m * p // 8,
        "centralized_over_dsml": (m * n * p * f32) / (m * p * f32),
    }


# Lowers the REAL sharded implementation (not a copy of it) and counts
# the collectives in its post-SPMD HLO; host-device/env plumbing comes
# from repro.substrate.run_probe.
_PROBE = r"""
import jax, re
from repro.substrate import task_mesh
from repro.core import gen_regression
from repro.core.dsml import dsml_sharded_fn

mesh = task_mesh(8)
data = gen_regression(jax.random.PRNGKey(0), m=8, n=50, p=200, s=10)
fn = dsml_sharded_fn(0.5, 0.2, 1.0, mesh, lasso_iters=200, debias_iters=200)
hlo = jax.jit(fn).lower(data.Xs, data.ys).compile().as_text()
kinds = re.findall(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(", hlo)
print("COLLECTIVES:" + ",".join(kinds))
"""


def verify_one_round() -> dict:
    """Run the 8-device shard_map probe in a subprocess; count collectives."""
    res = run_probe(_PROBE, n_devices=8, timeout=600)
    out = res.stdout + res.stderr
    m = re.search(r"COLLECTIVES:(.*)", out)
    kinds = [k for k in (m.group(1).split(",") if m else []) if k]
    return {
        "n_collectives": len(kinds),
        "kinds": kinds,
        "one_round": kinds == ["all-gather"],
        "probe_ok": res.returncode == 0,
    }


# Traces ONE sharded streaming ingest on an 8-device (data=4 x task=2)
# mesh and dumps the obs collective counters the substrate helpers
# recorded while tracing — the measured byte ledger for the
# psum-every-chunk path the one-shot protocol (ROADMAP item 3) will be
# benchmarked against.
_OBS_PROBE = r"""
import json
import jax, jax.numpy as jnp
from repro import obs
from repro.substrate import data_task_mesh
from repro.stream.accumulate import ingest_sharded
from repro.stream.state import init_stream_state

M, N, P = %(m)d, %(n)d, %(p)d
mesh = data_task_mesh(n_task=2)
obs.reset()
state = init_stream_state(M, P)
X = jnp.ones((M, N, P), jnp.float32)
y = jnp.ones((M, N), jnp.float32)
state = ingest_sharded(state, X, y, mesh)
jax.block_until_ready(state.Sigmas)
snap = obs.snapshot()
print("OBSJSON:" + json.dumps({
    "counters": snap["counters"],
    "data_size": mesh.shape["data"],
    "task_size": mesh.shape["task"],
}))
"""


def measured_collective_bytes(m: int = 8, n: int = 64,
                              p: int = 200) -> dict:
    """Measured bytes-on-the-wire for one sharded streaming ingest,
    read from the obs collective counters inside an 8-device probe.

    The byte model the counters implement (local-shard nbytes × axis
    participants) is cross-checked against the arithmetic expectation
    for this workload: the worker body psums its local (m_loc, p, p)
    Sigma block and (m_loc, p) c block over the `data` axis of size d,
    so each device wires d × (m_loc·p·p + m_loc·p) × 4 bytes. The
    shard_map body traces ONCE for all devices, so calls count traced
    collectives (per compilation), not per-device executions.
    """
    res = run_probe(_OBS_PROBE % {"m": m, "n": n, "p": p},
                    n_devices=8, timeout=600)
    out = res.stdout + res.stderr
    match = re.search(r"OBSJSON:(.*)", out)
    rec = {"probe_ok": res.returncode == 0 and match is not None,
           "psum_calls": 0, "psum_bytes": 0, "expected_bytes": 0,
           "matches_model": False}
    if not rec["probe_ok"]:
        return rec
    payload = json.loads(match.group(1))
    for c in payload["counters"]:
        if c["labels"].get("op") != "psum_stats":
            continue
        if c["name"] == "collective.calls":
            rec["psum_calls"] += int(c["value"])
        elif c["name"] == "collective.bytes":
            rec["psum_bytes"] += int(c["value"])
    d = payload["data_size"]
    m_loc = m // payload["task_size"]
    rec["expected_bytes"] = d * (m_loc * p * p * 4 + m_loc * p * 4)
    rec["matches_model"] = rec["psum_bytes"] == rec["expected_bytes"] > 0
    return rec


def main(out_dir: str = "experiments/paper"):
    t0 = time.time()
    bytes_rec = measured_bytes()
    probe = verify_one_round()
    obs_rec = measured_collective_bytes()
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "communication.json"), "w") as f:
        json.dump({"bytes": bytes_rec, "probe": probe,
                   "measured": obs_rec}, f, indent=2)
    dt = (time.time() - t0) * 1e6
    return [
        f"comm_lasso_bytes,{dt:.0f},0",
        f"comm_group_lasso_bytes,{dt:.0f},{bytes_rec['group_lasso_centralized']}",
        f"comm_dsml_bytes,{dt:.0f},{bytes_rec['dsml_total']}",
        f"comm_ratio_central_over_dsml,{dt:.0f},{bytes_rec['centralized_over_dsml']:.1f}",
        f"comm_dsml_one_allgather,{dt:.0f},{probe['one_round']}",
        f"comm_measured_psum_calls,{dt:.0f},{obs_rec['psum_calls']}",
        f"comm_measured_psum_bytes,{dt:.0f},{obs_rec['psum_bytes']},"
        f"matches_model={obs_rec['matches_model']}",
    ]


if __name__ == "__main__":
    for r in main():
        print(r)
