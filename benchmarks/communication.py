"""Paper Table 1, communication column: measured bytes per worker.

  local lasso        0                   (no communication)
  group lasso        O(np)  per worker   (centralizing the raw data)
  DSML               O(p)   per worker   (ONE debiased p-vector up,
                                          p-bit support mask down)

Bytes are measured from the actual arrays the implementation ships, and
the DSML one-round property is verified structurally: the SPMD HLO of
`dsml_fit_sharded` contains exactly ONE all-gather collective.
"""
from __future__ import annotations

import json
import os
import re
import time

from repro.substrate import run_probe


def measured_bytes(m: int = 10, n: int = 50, p: int = 200) -> dict:
    f32 = 4
    return {
        "lasso": 0,
        "group_lasso_centralized": m * n * p * f32 + m * n * f32,  # X_t, y_t
        "dsml_up": m * p * f32,                # debiased vectors to master
        "dsml_down": m * p // 8,               # support bitmask broadcast
        "dsml_total": m * p * f32 + m * p // 8,
        "centralized_over_dsml": (m * n * p * f32) / (m * p * f32),
    }


# Lowers the REAL sharded implementation (not a copy of it) and counts
# the collectives in its post-SPMD HLO; host-device/env plumbing comes
# from repro.substrate.run_probe.
_PROBE = r"""
import jax, re
from repro.substrate import task_mesh
from repro.core import gen_regression
from repro.core.dsml import dsml_sharded_fn

mesh = task_mesh(8)
data = gen_regression(jax.random.PRNGKey(0), m=8, n=50, p=200, s=10)
fn = dsml_sharded_fn(0.5, 0.2, 1.0, mesh, lasso_iters=200, debias_iters=200)
hlo = jax.jit(fn).lower(data.Xs, data.ys).compile().as_text()
kinds = re.findall(r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\(", hlo)
print("COLLECTIVES:" + ",".join(kinds))
"""


def verify_one_round() -> dict:
    """Run the 8-device shard_map probe in a subprocess; count collectives."""
    res = run_probe(_PROBE, n_devices=8, timeout=600)
    out = res.stdout + res.stderr
    m = re.search(r"COLLECTIVES:(.*)", out)
    kinds = [k for k in (m.group(1).split(",") if m else []) if k]
    return {
        "n_collectives": len(kinds),
        "kinds": kinds,
        "one_round": kinds == ["all-gather"],
        "probe_ok": res.returncode == 0,
    }


def main(out_dir: str = "experiments/paper"):
    t0 = time.time()
    bytes_rec = measured_bytes()
    probe = verify_one_round()
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "communication.json"), "w") as f:
        json.dump({"bytes": bytes_rec, "probe": probe}, f, indent=2)
    dt = (time.time() - t0) * 1e6
    return [
        f"comm_lasso_bytes,{dt:.0f},0",
        f"comm_group_lasso_bytes,{dt:.0f},{bytes_rec['group_lasso_centralized']}",
        f"comm_dsml_bytes,{dt:.0f},{bytes_rec['dsml_total']}",
        f"comm_ratio_central_over_dsml,{dt:.0f},{bytes_rec['centralized_over_dsml']:.1f}",
        f"comm_dsml_one_allgather,{dt:.0f},{probe['one_round']}",
    ]


if __name__ == "__main__":
    for r in main():
        print(r)
