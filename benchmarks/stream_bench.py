"""Streaming DSML benchmarks: ingest throughput and warm vs cold refit.

Ingest is the always-on cost (one rank-n update per chunk: O(m n p^2)
FLOPs, no solver); refit is the occasional cost. Warm-started refits
matter because consecutive refits see nearly identical statistics —
the bench finds the smallest warm iteration budget that matches the
cold solve's accuracy against a high-iteration reference, then times
both. With >1 device (e.g. `make bench-stream-smoke` forcing 8 host
devices) the SPMD data x task accumulator is timed as well.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.paper_common import time_fn as _time
from repro.core import gen_regression
from repro.stream import ingest, init_stream_state, refit
from repro.stream.accumulate import ingest_sharded


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small CI sizes")
    args = ap.parse_args(argv)
    m, p, n_chunk = (4, 64, 256) if args.smoke else (8, 256, 1024)
    cold_iters = 200 if args.smoke else 400
    rows = []

    data = gen_regression(jax.random.PRNGKey(0), m=m, n=4 * n_chunk, p=p,
                          s=max(p // 20, 3))
    chunks = list(zip(jnp.split(data.Xs, 4, axis=1),
                      jnp.split(data.ys, 4, axis=1)))
    lam, mu, Lam = 0.4, 0.2, 1.0

    # -- ingest throughput -------------------------------------------------
    state = init_stream_state(m, p)
    us = _time(ingest, state, *chunks[0])
    rows.append(f"stream_ingest_m{m}_n{n_chunk}_p{p},{us:.0f},"
                f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    if jax.device_count() > 1:
        from repro.substrate import data_task_mesh
        mesh = data_task_mesh(n_task=2)
        f = lambda s, X, y: ingest_sharded(s, X, y, mesh)
        us = _time(f, state, *chunks[0])
        rows.append(f"stream_ingest_sharded_{dict(mesh.shape)},{us:.0f},"
                    f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    # -- warm vs cold refit ------------------------------------------------
    # state after 3 chunks, refitted (the "previous" model), plus one more
    # chunk of drifted statistics — the steady-state refit situation.
    for Xc, yc in chunks[:3]:
        state = ingest(state, Xc, yc)
    state, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                     debias_iters=cold_iters)
    state = ingest(state, *chunks[3])

    ref, _ = refit(state, lam, mu, Lam, lasso_iters=5 * cold_iters,
                   debias_iters=5 * cold_iters)
    cold, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                    debias_iters=cold_iters, warm=False)
    err_cold = float(jnp.max(jnp.abs(cold.beta_tilde - ref.beta_tilde)))

    warm_iters = cold_iters
    for k in (cold_iters // 16, cold_iters // 8, cold_iters // 4,
              cold_iters // 2):
        warm, _ = refit(state, lam, mu, Lam, lasso_iters=k,
                        debias_iters=k, warm=True)
        err = float(jnp.max(jnp.abs(warm.beta_tilde - ref.beta_tilde)))
        if err <= max(err_cold, 1e-6):
            warm_iters = k
            break

    reps = 10 if args.smoke else 3
    t_cold = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=cold_iters,
                                   debias_iters=cold_iters, warm=False),
                   state, reps=reps)
    t_warm = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=warm_iters,
                                   debias_iters=warm_iters, warm=True),
                   state, reps=reps)
    rows.append(f"stream_refit_cold_iters{cold_iters},{t_cold:.0f},"
                f"err={err_cold:.2e}")
    rows.append(f"stream_refit_warm_iters{warm_iters},{t_warm:.0f},"
                f"speedup={t_cold / t_warm:.2f}x")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
