"""Streaming DSML benchmarks: ingest throughput and warm vs cold refit.

Ingest is the always-on cost (one rank-n update per chunk: O(m n p^2)
FLOPs, no solver); refit is the occasional cost. Warm-started refits
matter because consecutive refits see nearly identical statistics —
the bench finds the smallest warm iteration budget that matches the
cold solve's accuracy against a high-iteration reference, then times
both. With >1 device (e.g. `make bench-stream-smoke` forcing 8 host
devices) the SPMD data x task accumulator is timed as well.

An instrumented pass replays ingest + refit under the same
`stream.ingest` / `stream.refit` span names the service layer uses, so
the `stream_obs_*` rows and `--obs-out` artifacts exercise the exact
telemetry a deployed `StreamingDsmlService` emits (`make obs-report`
summarizes them). With REPRO_OBS=0 those rows degrade to zeros instead
of failing — the disabled path must stay runnable.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.paper_common import time_fn as _time
from repro import obs
from repro.core import gen_regression
from repro.stream import ingest, init_stream_state, refit
from repro.stream.accumulate import ingest_sharded


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small CI sizes")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="write the obs snapshot (and a .trace.json "
                         "Chrome trace next to it) after the bench")
    args = ap.parse_args(argv)
    m, p, n_chunk = (4, 64, 256) if args.smoke else (8, 256, 1024)
    cold_iters = 200 if args.smoke else 400
    rows = []

    data = gen_regression(jax.random.PRNGKey(0), m=m, n=4 * n_chunk, p=p,
                          s=max(p // 20, 3))
    chunks = list(zip(jnp.split(data.Xs, 4, axis=1),
                      jnp.split(data.ys, 4, axis=1)))
    lam, mu, Lam = 0.4, 0.2, 1.0

    # -- ingest throughput -------------------------------------------------
    state = init_stream_state(m, p)
    us = _time(ingest, state, *chunks[0])
    rows.append(f"stream_ingest_m{m}_n{n_chunk}_p{p},{us:.0f},"
                f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    if jax.device_count() > 1:
        from repro.substrate import data_task_mesh
        mesh = data_task_mesh(n_task=2)
        f = lambda s, X, y: ingest_sharded(s, X, y, mesh)
        us = _time(f, state, *chunks[0])
        rows.append(f"stream_ingest_sharded_{dict(mesh.shape)},{us:.0f},"
                    f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    # -- warm vs cold refit ------------------------------------------------
    # state after 3 chunks, refitted (the "previous" model), plus one more
    # chunk of drifted statistics — the steady-state refit situation.
    for Xc, yc in chunks[:3]:
        state = ingest(state, Xc, yc)
    state, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                     debias_iters=cold_iters)
    state = ingest(state, *chunks[3])

    ref, _ = refit(state, lam, mu, Lam, lasso_iters=5 * cold_iters,
                   debias_iters=5 * cold_iters)
    cold, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                    debias_iters=cold_iters, warm=False)
    err_cold = float(jnp.max(jnp.abs(cold.beta_tilde - ref.beta_tilde)))

    warm_iters = cold_iters
    for k in (cold_iters // 16, cold_iters // 8, cold_iters // 4,
              cold_iters // 2):
        warm, _ = refit(state, lam, mu, Lam, lasso_iters=k,
                        debias_iters=k, warm=True)
        err = float(jnp.max(jnp.abs(warm.beta_tilde - ref.beta_tilde)))
        if err <= max(err_cold, 1e-6):
            warm_iters = k
            break

    reps = 10 if args.smoke else 3
    t_cold = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=cold_iters,
                                   debias_iters=cold_iters, warm=False),
                   state, reps=reps)
    t_warm = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=warm_iters,
                                   debias_iters=warm_iters, warm=True),
                   state, reps=reps)
    rows.append(f"stream_refit_cold_iters{cold_iters},{t_cold:.0f},"
                f"err={err_cold:.2e}")
    rows.append(f"stream_refit_warm_iters{warm_iters},{t_warm:.0f},"
                f"speedup={t_cold / t_warm:.2f}x")

    # -- instrumented pass: service-layer span names ----------------------
    # blocked inside the span so the ingest span measures completed work
    # here (the service's own span is a dispatch-latency upper bound)
    for Xc, yc in chunks:
        with obs.span("stream.ingest"):
            jax.block_until_ready(ingest(state, Xc, yc))
        obs.inc("stream.ingest.rows", m * n_chunk)
    with obs.span("stream.refit"):
        jax.block_until_ready(refit(state, lam, mu, Lam,
                                    lasso_iters=warm_iters,
                                    debias_iters=warm_iters, warm=True)[0])
    obs.set_gauge("stream.bench.ingest_rows_per_s",
                  m * n_chunk / (us * 1e-6))
    obs.set_gauge("stream.bench.refit_cold_us", t_cold)
    obs.set_gauge("stream.bench.refit_warm_us", t_warm)

    ing = obs.hist_stats("stream.ingest.ms")
    ref_ms = obs.hist_stats("stream.refit.ms")
    ing_rows = obs.counter_total("stream.ingest.rows")
    obs_rate = (ing_rows / (ing["sum"] * 1e-3)
                if ing and ing["sum"] > 0 else 0.0)
    rows.append(f"stream_obs_ingest_rate,"
                f"{ing['mean'] * 1e3 if ing else 0:.0f},"
                f"rows_per_s={obs_rate:.0f}")
    rows.append(f"stream_obs_refit_latency,"
                f"{ref_ms['mean'] * 1e3 if ref_ms else 0:.0f},"
                f"refits={ref_ms['count'] if ref_ms else 0}")

    if args.obs_out:
        from repro.obs import export as obs_export
        obs_export.write_snapshot(args.obs_out,
                                  meta={"bench": "stream",
                                        "smoke": bool(args.smoke)})
        base = args.obs_out[:-5] if args.obs_out.endswith(".json") \
            else args.obs_out
        obs_export.write_chrome_trace(base + ".trace.json")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
