"""Streaming DSML benchmarks: ingest throughput and warm vs cold refit.

Ingest is the always-on cost (one rank-n update per chunk: O(m n p^2)
FLOPs, no solver); refit is the occasional cost. Warm-started refits
matter because consecutive refits see nearly identical statistics —
the bench finds the smallest warm iteration budget that matches the
cold solve's accuracy against a high-iteration reference, then times
both. With >1 device (e.g. `make bench-stream-smoke` forcing 8 host
devices) the SPMD data x task accumulator is timed as well.

An instrumented pass replays ingest + refit under the same
`stream.ingest` / `stream.refit` span names the service layer uses, so
the `stream_obs_*` rows and `--obs-out` artifacts exercise the exact
telemetry a deployed `StreamingDsmlService` emits (`make obs-report`
summarizes them). With REPRO_OBS=0 those rows degrade to zeros instead
of failing — the disabled path must stay runnable.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.paper_common import time_fn as _time
from repro import obs
from repro.core import gen_regression
from repro.stream import (
    ServingFront, StreamingDsmlService, ingest, init_stream_state, refit,
)
from repro.stream.accumulate import ingest_sharded


def serve_rows(smoke: bool = True):
    """The serving-front rows: request p99 under a closed-loop predict
    load, then SUSTAINED ingest rows/sec while that load keeps running
    — the millions-of-users artifact (ROADMAP item 1). Latencies are
    measured client-side (perf_counter around each resolved future) so
    the quantiles cover the full admission -> microbatch -> dispatch ->
    result path; `benchmarks/check_regression.py` bounds the p99 and
    the while-serving ingest floor from the committed BENCH_serve.json.
    """
    m, p, n_chunk = (4, 64, 256) if smoke else (8, 256, 1024)
    n_clients = 4
    serve_seconds = 1.0 if smoke else 3.0
    rows = []
    rng = np.random.default_rng(0)
    svc = StreamingDsmlService(
        m, p, lam=0.4, mu=0.2, Lam=1.0, guard=False,
        refit_every=n_chunk, max_refit_interval=4 * n_chunk,
        lasso_iters=200, debias_iters=200, refit_tol=1e-5)

    def chunk():
        X = rng.standard_normal((m, n_chunk, p)).astype(np.float32)
        w = rng.standard_normal((m, p)).astype(np.float32) / np.sqrt(p)
        y = (np.einsum("tnp,tp->tn", X, w)
             + 0.05 * rng.standard_normal((m, n_chunk))).astype(np.float32)
        return jnp.asarray(X), jnp.asarray(y)

    svc.ingest(*chunk())                      # a real model + compiles
    query = rng.standard_normal(p).astype(np.float32)

    def load(front, stop, out):
        """One closed-loop client: predict, note latency, repeat."""
        lats = []
        while not stop.is_set():
            t0 = time.perf_counter()
            front.predict(query, timeout=30)
            lats.append((time.perf_counter() - t0) * 1e3)
        out.append(lats)

    def run_phase(seconds, feeder=None):
        """Drive the client pool for `seconds` (while `feeder` folds
        chunks, when given); returns (client latencies ms, chunks fed)."""
        with ServingFront(svc, max_batch=64, max_delay_ms=2.0) as front:
            front.predict(query, timeout=30)  # compile outside the clock
            stop, out = threading.Event(), []
            clients = [threading.Thread(target=load,
                                        args=(front, stop, out))
                       for _ in range(n_clients)]
            for c in clients:
                c.start()
            fed = 0
            deadline = time.perf_counter() + seconds
            if feeder is not None:
                while time.perf_counter() < deadline:
                    feeder()
                    fed += 1
                jax.block_until_ready(svc.state.Sigmas)
            else:
                while time.perf_counter() < deadline:
                    time.sleep(0.01)
            stop.set()
            for c in clients:
                c.join()
        return [v for lats in out for v in lats], fed

    # -- phase 1: serve-only p99 ------------------------------------------
    lats, _ = run_phase(serve_seconds)
    p50, p99 = np.percentile(lats, [50, 99])
    rows.append(f"stream_serve_p99_ms,{np.mean(lats) * 1e3:.0f},"
                f"p50_ms={p50:.2f},p99_ms={p99:.2f},requests={len(lats)}")
    obs.set_gauge("serve.bench.p99_ms", float(p99))

    # -- phase 2: sustained ingest under the same predict load ------------
    t0 = time.perf_counter()
    lats, fed = run_phase(serve_seconds,
                          feeder=lambda: svc.ingest(*chunk()))
    elapsed = time.perf_counter() - t0
    rate = m * n_chunk * fed / elapsed
    p50, p99 = np.percentile(lats, [50, 99])
    us_chunk = elapsed / max(fed, 1) * 1e6
    rows.append(f"stream_ingest_while_serving,{us_chunk:.0f},"
                f"rows_per_s={rate:.0f},p50_ms={p50:.2f},"
                f"p99_ms={p99:.2f},chunks={fed},requests={len(lats)}")
    obs.set_gauge("serve.bench.ingest_while_serving_rows_per_s",
                  float(rate))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true", help="small CI sizes")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="write the obs snapshot (and a .trace.json "
                         "Chrome trace next to it) after the bench")
    args = ap.parse_args(argv)
    m, p, n_chunk = (4, 64, 256) if args.smoke else (8, 256, 1024)
    cold_iters = 200 if args.smoke else 400
    rows = []

    data = gen_regression(jax.random.PRNGKey(0), m=m, n=4 * n_chunk, p=p,
                          s=max(p // 20, 3))
    chunks = list(zip(jnp.split(data.Xs, 4, axis=1),
                      jnp.split(data.ys, 4, axis=1)))
    lam, mu, Lam = 0.4, 0.2, 1.0

    # -- ingest throughput -------------------------------------------------
    state = init_stream_state(m, p)
    us = _time(ingest, state, *chunks[0])
    rows.append(f"stream_ingest_m{m}_n{n_chunk}_p{p},{us:.0f},"
                f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    if jax.device_count() > 1:
        from repro.substrate import data_task_mesh
        mesh = data_task_mesh(n_task=2)
        f = lambda s, X, y: ingest_sharded(s, X, y, mesh)
        us = _time(f, state, *chunks[0])
        rows.append(f"stream_ingest_sharded_{dict(mesh.shape)},{us:.0f},"
                    f"rows_per_s={m * n_chunk / (us * 1e-6):.0f}")

    # -- warm vs cold refit ------------------------------------------------
    # state after 3 chunks, refitted (the "previous" model), plus one more
    # chunk of drifted statistics — the steady-state refit situation.
    for Xc, yc in chunks[:3]:
        state = ingest(state, Xc, yc)
    state, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                     debias_iters=cold_iters)
    state = ingest(state, *chunks[3])

    ref, _ = refit(state, lam, mu, Lam, lasso_iters=5 * cold_iters,
                   debias_iters=5 * cold_iters)
    cold, _ = refit(state, lam, mu, Lam, lasso_iters=cold_iters,
                    debias_iters=cold_iters, warm=False)
    err_cold = float(jnp.max(jnp.abs(cold.beta_tilde - ref.beta_tilde)))

    warm_iters = cold_iters
    for k in (cold_iters // 16, cold_iters // 8, cold_iters // 4,
              cold_iters // 2):
        warm, _ = refit(state, lam, mu, Lam, lasso_iters=k,
                        debias_iters=k, warm=True)
        err = float(jnp.max(jnp.abs(warm.beta_tilde - ref.beta_tilde)))
        if err <= max(err_cold, 1e-6):
            warm_iters = k
            break

    reps = 10 if args.smoke else 3
    t_cold = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=cold_iters,
                                   debias_iters=cold_iters, warm=False),
                   state, reps=reps)
    t_warm = _time(lambda s: refit(s, lam, mu, Lam, lasso_iters=warm_iters,
                                   debias_iters=warm_iters, warm=True),
                   state, reps=reps)
    rows.append(f"stream_refit_cold_iters{cold_iters},{t_cold:.0f},"
                f"err={err_cold:.2e}")
    rows.append(f"stream_refit_warm_iters{warm_iters},{t_warm:.0f},"
                f"speedup={t_cold / t_warm:.2f}x")

    # -- instrumented pass: service-layer span names ----------------------
    # blocked inside the span so the ingest span measures completed work
    # here (the service's own span is a dispatch-latency upper bound)
    for Xc, yc in chunks:
        with obs.span("stream.ingest"):
            jax.block_until_ready(ingest(state, Xc, yc))
        obs.inc("stream.ingest.rows", m * n_chunk)
    with obs.span("stream.refit"):
        jax.block_until_ready(refit(state, lam, mu, Lam,
                                    lasso_iters=warm_iters,
                                    debias_iters=warm_iters, warm=True)[0])
    obs.set_gauge("stream.bench.ingest_rows_per_s",
                  m * n_chunk / (us * 1e-6))
    obs.set_gauge("stream.bench.refit_cold_us", t_cold)
    obs.set_gauge("stream.bench.refit_warm_us", t_warm)

    ing = obs.hist_stats("stream.ingest.ms")
    ref_ms = obs.hist_stats("stream.refit.ms")
    ing_rows = obs.counter_total("stream.ingest.rows")
    obs_rate = (ing_rows / (ing["sum"] * 1e-3)
                if ing and ing["sum"] > 0 else 0.0)
    rows.append(f"stream_obs_ingest_rate,"
                f"{ing['mean'] * 1e3 if ing else 0:.0f},"
                f"rows_per_s={obs_rate:.0f}")
    rows.append(f"stream_obs_refit_latency,"
                f"{ref_ms['mean'] * 1e3 if ref_ms else 0:.0f},"
                f"refits={ref_ms['count'] if ref_ms else 0}")

    # -- serving front: p99 under load + ingest-while-serving -------------
    rows.extend(serve_rows(smoke=args.smoke))

    if args.obs_out:
        from repro.obs import export as obs_export
        obs_export.write_snapshot(args.obs_out,
                                  meta={"bench": "stream",
                                        "smoke": bool(args.smoke)})
        base = args.obs_out[:-5] if args.obs_out.endswith(".json") \
            else args.obs_out
        obs_export.write_chrome_trace(base + ".trace.json")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
