"""Shared machinery for the paper-experiment benchmarks (Figures 1-2).

Methods compared (paper Section 6): local lasso, group lasso, refitted
group lasso, iCAP, DSML, refitted DSML. Regularization / thresholding
parameters are tuned for best Hamming error on each configuration,
exactly as the paper tunes them.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import jax.numpy as jnp

from repro.core import (
    dsml_fit, dsml_logistic_fit, estimation_error, gen_classification,
    gen_regression, group_lasso, group_logistic_lasso, hamming, icap,
    icap_logistic, prediction_error,
    refit_logistic_masked, refit_ols_masked_stats, sufficient_stats,
    support_of, support_from_rows,
)
from repro.core.engine import solve_lasso_eq2_grid, solve_logistic_lasso_batched

LAM_GRID = (0.5, 1.0, 2.0, 4.0)          # multiples of sigma*sqrt(log p / n)
THRESH_QUANTILES = (0.5, 0.7, 0.8, 0.9, 0.95, 0.98)


def _base_lam(n: int, p: int, sigma: float = 1.0) -> float:
    return float(sigma * jnp.sqrt(jnp.log(float(p)) / n))


def time_fn(fn: Callable, *args, reps: int = 10) -> float:
    """Mean wall time of `fn(*args)` in microseconds.

    The warm-up call is synced before timing starts so compile time
    never leaks into the first rep. Shared by the kernel and streaming
    microbenchmarks.
    """
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def _best_by_hamming(candidates, support_true):
    best = None
    for B_hat, extra in candidates:
        h = int(hamming(support_of(B_hat, 1e-3), support_true))
        if best is None or h < best[0]:
            best = (h, B_hat, extra)
    return best


def eval_regression_methods(data, *, iters: int = 400) -> Dict[str, dict]:
    """Run every method on one dataset; returns metrics per method."""
    Xs, ys, B_true, support, Sigma = data
    m, n, p = Xs.shape
    base = _base_lam(n, p)
    out: Dict[str, dict] = {}

    def record(name, B_hat):
        out[name] = {
            "hamming": int(hamming(support_of(B_hat, 1e-3), support)),
            "est_err": float(estimation_error(B_hat, B_true)),
            "pred_err": float(prediction_error(B_hat, B_true, Sigma)),
        }

    # --- local lasso (per-task, tuned): the whole lambda grid x tasks
    # sweep is ONE batched sufficient-statistics engine call ---
    Sigmas, cs = sufficient_stats(Xs, ys)
    lam_grid = jnp.asarray([c * base * 4 for c in LAM_GRID])
    B_grid = solve_lasso_eq2_grid(Sigmas, cs, lam_grid, iters=iters)
    cands = [(B_grid[i].T, None) for i in range(len(LAM_GRID))]
    _, B_best, _ = _best_by_hamming(cands, support)
    record("lasso", B_best)

    # --- group lasso (tuned) + refit ---
    cands = []
    for c in LAM_GRID:
        Bg = group_lasso(Xs, ys, c * base, iters=iters)
        cands.append((Bg, None))
    _, B_best, _ = _best_by_hamming(cands, support)
    record("group_lasso", B_best)
    sup = support_of(B_best, 1e-3)
    B_refit = jax.vmap(
        lambda S, c: refit_ols_masked_stats(S, c, sup))(Sigmas, cs).T
    record("refit_group_lasso", B_refit)

    # --- iCAP (tuned) ---
    cands = []
    for c in (1.0, 2.0, 4.0, 8.0):
        Bi = icap(Xs, ys, c * base, iters=iters)
        cands.append((Bi, None))
    _, B_best, _ = _best_by_hamming(cands, support)
    record("icap", B_best)

    # --- DSML: lam/mu at the theory values, Lambda tuned (as the paper) ---
    lam = 4.0 * base
    mu = base
    res0 = dsml_fit(Xs, ys, lam, mu, Lam=0.0)       # debiased estimates
    norms = jnp.linalg.norm(res0.beta_u.T, axis=-1)
    cands = []
    for q in THRESH_QUANTILES:
        Lam = float(jnp.quantile(norms, q))
        sup_hat = support_from_rows(res0.beta_u.T, Lam)
        B_hat = (res0.beta_u * sup_hat[None, :]).T
        cands.append((B_hat, sup_hat))
    h, B_best, sup_hat = _best_by_hamming(cands, support)
    record("dsml", B_best)
    B_refit = jax.vmap(
        lambda S, c: refit_ols_masked_stats(S, c, sup_hat))(Sigmas, cs).T
    record("refit_dsml", B_refit)
    return out


def eval_classification_methods(data, data_test, *, iters: int = 500) -> Dict[str, dict]:
    Xs, ys, B_true, support, Sigma = data
    m, n, p = Xs.shape
    base = _base_lam(n, p)
    out: Dict[str, dict] = {}

    def record(name, B_hat):
        from repro.core import classification_error
        out[name] = {
            "hamming": int(hamming(support_of(B_hat, 1e-3), support)),
            "est_err": float(estimation_error(B_hat, B_true)),
            "pred_err": float(classification_error(B_hat, data_test.Xs,
                                                   data_test.ys)),
        }

    cands = []
    for c in LAM_GRID:
        # all m per-task l1-logistic solves in ONE engine-v2 batched loop
        Bl = solve_logistic_lasso_batched(Xs, ys, c * base, iters=iters).T
        cands.append((Bl, None))
    _, B_best, _ = _best_by_hamming(cands, support)
    record("lasso", B_best)

    cands = []
    for c in (0.05, 0.125, 0.25, 0.5, 1.0):   # logistic grads ~4x smaller
        Bg = group_logistic_lasso(Xs, ys, c * base, iters=iters)
        cands.append((Bg, None))
    _, B_best, _ = _best_by_hamming(cands, support)
    record("group_lasso", B_best)
    sup = support_of(B_best, 1e-3)
    B_refit = jax.vmap(lambda X, y: refit_logistic_masked(X, y, sup))(Xs, ys).T
    record("refit_group_lasso", B_refit)

    cands = []
    for c in (0.125, 0.25, 0.5, 1.0, 2.0):
        Bi = icap_logistic(Xs, ys, c * base, iters=iters)
        cands.append((Bi, None))
    _, B_best, _ = _best_by_hamming(cands, support)
    record("icap", B_best)

    res0 = dsml_logistic_fit(Xs, ys, base, 2.0 * base, Lam=0.0,
                             lasso_iters=iters, debias_iters=iters)
    norms = jnp.linalg.norm(res0.beta_u.T, axis=-1)
    cands = []
    for q in THRESH_QUANTILES:
        Lam = float(jnp.quantile(norms, q))
        sup_hat = support_from_rows(res0.beta_u.T, Lam)
        B_hat = (res0.beta_u * sup_hat[None, :]).T
        cands.append((B_hat, sup_hat))
    h, B_best, sup_hat = _best_by_hamming(cands, support)
    record("dsml", B_best)
    B_refit = jax.vmap(lambda X, y: refit_logistic_masked(X, y, sup_hat))(Xs, ys).T
    record("refit_dsml", B_refit)
    return out


def average_runs(run_fn: Callable[[jax.Array], Dict[str, dict]],
                 n_runs: int, seed: int = 0) -> Dict[str, dict]:
    """Average metric dicts over independent runs."""
    acc: Dict[str, dict] = {}
    for i in range(n_runs):
        res = run_fn(jax.random.PRNGKey(seed + 1000 * i))
        for meth, met in res.items():
            slot = acc.setdefault(meth, {k: 0.0 for k in met})
            for k, v in met.items():
                slot[k] += v / n_runs
    return acc
