"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (the repo contract). Heavy
experiment sweeps persist JSON artifacts under experiments/paper/.

  fig1   — paper Figure 1 (regression, vary n / vary m)
  fig2   — paper Figure 2 (classification, vary n / vary m)
  comm   — paper Table 1 communication column (+ one-round HLO proof)
  rates  — Tables 1-2 rate sanity (error scaling vs n and m)
  kern   — kernel microbenches
  roof   — dry-run / roofline summary (reads experiments/dryrun)

Usage: python -m benchmarks.run [--only fig1,comm] [--runs N]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,comm,rates,kern,roof")
    ap.add_argument("--runs", type=int, default=5,
                    help="averaging runs for the paper sweeps")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    sections = []
    if want is None or "comm" in want:
        from benchmarks.communication import main as comm_main
        sections.append(("comm", comm_main))
    if want is None or "kern" in want:
        from benchmarks.kernels_bench import main as kern_main
        sections.append(("kern", kern_main))
    if want is None or "rates" in want:
        from benchmarks.rates import main as rates_main
        sections.append(("rates",
                         lambda: rates_main(n_runs=max(3, args.runs // 2))))
    if want is None or "fig1" in want:
        from benchmarks.fig1_regression import main as fig1_main
        sections.append(("fig1", lambda: fig1_main(n_runs=args.runs)))
    if want is None or "fig2" in want:
        from benchmarks.fig2_classification import main as fig2_main
        sections.append(("fig2", lambda: fig2_main(n_runs=args.runs)))
    if want is None or "roof" in want:
        from benchmarks.roofline import main as roof_main
        sections.append(("roof", roof_main))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in sections:
        try:
            for row in fn():
                print(row, flush=True)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,see stderr", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
