"""Benchmark driver: one section per paper table/figure + system benches.

Prints ``name,us_per_call,derived`` CSV rows (the repo contract). Heavy
experiment sweeps persist JSON artifacts under experiments/paper/.

  fig1   — paper Figure 1 (regression, vary n / vary m)
  fig2   — paper Figure 2 (classification, vary n / vary m)
  comm   — paper Table 1 communication column (+ one-round HLO proof)
  rates  — Tables 1-2 rate sanity (error scaling vs n and m)
  kern   — kernel microbenches
  serve  — streaming serving front (p99 under load, ingest-while-serving)
  roof   — dry-run / roofline summary (reads experiments/dryrun)

Usage: python -m benchmarks.run [--only fig1,comm] [--runs N]
                                [--json-out BENCH_kernels.json]
                                [--telemetry PATH]

`--json-out` additionally persists the machine-readable sections (kern
and serve) as JSON: `{"meta": {...}, "rows": [...]}` — run metadata
(backend, device count, jax version, git SHA) plus the final telemetry
snapshot under `meta`, one object per benchmark row (name/us plus any
derived fields like flops and speedup) under `rows` — so the perf
trajectory is tracked across PRs AND attributable to the environment
that produced it. Select ONE machine-readable section per artifact
(`--only kern --json-out BENCH_kernels.json`, `--only serve --json-out
BENCH_serve.json`); `benchmarks/check_regression.py` gates on both
files (it also still reads the pre-PR-7 flat-list format).
`--telemetry PATH` writes the full obs snapshot of the whole benchmark
run as its own artifact.
"""
from __future__ import annotations

import argparse
import json
import sys
import traceback


def run_metadata() -> dict:
    """Environment stamp for benchmark artifacts. Imports jax lazily —
    this module must stay importable (for `rows_to_json`) without
    paying a backend init."""
    import platform
    import subprocess

    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=__file__.rsplit("/", 2)[0] or ".",
        ).stdout.strip() or "unknown"
    except (OSError, subprocess.SubprocessError):
        sha = "unknown"
    return {
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": sha,
    }


def rows_to_json(rows) -> list:
    """Parse ``name,us,k=v,...`` benchmark rows into JSON objects.

    Numeric derived fields are parsed as floats (a trailing ``x`` on
    speedups is stripped); anything unparsable stays a string.
    """
    out = []
    for row in rows:
        parts = row.split(",")
        d = {"name": parts[0], "us": float(parts[1])}
        for extra in parts[2:]:
            k, _, v = extra.partition("=")
            try:
                d[k] = float(v[:-1] if v.endswith("x") else v)
            except ValueError:
                d[k] = v
        out.append(d)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig1,fig2,comm,rates,kern,serve,roof")
    ap.add_argument("--runs", type=int, default=5,
                    help="averaging runs for the paper sweeps")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the machine-readable rows (kern / "
                         "serve sections) as JSON to PATH")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="write the run's repro.obs snapshot to PATH")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    sections = []
    if want is None or "comm" in want:
        from benchmarks.communication import main as comm_main
        sections.append(("comm", comm_main))
    if want is None or "kern" in want:
        from benchmarks.kernels_bench import main as kern_main
        sections.append(("kern", kern_main))
    if want is None or "serve" in want:
        from benchmarks.stream_bench import serve_rows as serve_main
        sections.append(("serve", lambda: serve_main(smoke=True)))
    if want is None or "rates" in want:
        from benchmarks.rates import main as rates_main
        sections.append(("rates",
                         lambda: rates_main(n_runs=max(3, args.runs // 2))))
    if want is None or "fig1" in want:
        from benchmarks.fig1_regression import main as fig1_main
        sections.append(("fig1", lambda: fig1_main(n_runs=args.runs)))
    if want is None or "fig2" in want:
        from benchmarks.fig2_classification import main as fig2_main
        sections.append(("fig2", lambda: fig2_main(n_runs=args.runs)))
    if want is None or "roof" in want:
        from benchmarks.roofline import main as roof_main
        sections.append(("roof", roof_main))

    print("name,us_per_call,derived")
    failures = 0
    json_rows = []   # rows from machine-readable sections, in run order
    JSONABLE = {"kern", "serve"}
    for name, fn in sections:
        try:
            rows = fn()
            for row in rows:
                print(row, flush=True)
            if name in JSONABLE and args.json_out:
                json_rows.extend(rows)
        except Exception:
            failures += 1
            print(f"{name}_FAILED,0,see stderr", flush=True)
            traceback.print_exc()
    if args.json_out and json_rows:
        from repro import obs
        artifact = {
            "meta": {**run_metadata(), "telemetry": obs.snapshot()},
            "rows": rows_to_json(json_rows),
        }
        with open(args.json_out, "w") as f:
            json.dump(artifact, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.json_out}", file=sys.stderr)
    if args.telemetry:
        from repro.obs import export as obs_export
        obs_export.write_snapshot(args.telemetry, meta=run_metadata())
        print(f"# wrote {args.telemetry}", file=sys.stderr)
    if args.json_out and not json_rows:
        # never exit 0 leaving a stale baseline: no machine-readable
        # section ran to completion, so the requested JSON was not
        # produced
        print(f"ERROR: --json-out {args.json_out} requested but no "
              "machine-readable section (kern/serve) ran to completion",
              file=sys.stderr)
        failures += 1
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
