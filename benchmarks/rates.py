"""Tables 1-2 rate sanity: empirical error scaling of DSML vs theory.

Corollary 2 predicts estimation error ~ |S| * sqrt((m + log p)/n) to
leading order: doubling n should shrink the error by ~sqrt(2) (slope -1/2
on a log-log plot), and the per-task-normalized error should IMPROVE as m
grows (the log(p)/m term) — the transfer benefit the paper is about.
"""
from __future__ import annotations

import json
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dsml_fit, estimation_error, gen_regression


def _dsml_err(key, m, n, p=200, s=10):
    data = gen_regression(key, m=m, n=n, p=p, s=s, signal_low=0.3)
    base = float(jnp.sqrt(jnp.log(float(p)) / n))
    res = dsml_fit(data.Xs, data.ys, 4 * base, base, Lam=0.0)
    norms = jnp.linalg.norm(res.beta_u.T, axis=-1)
    Lam = float(jnp.quantile(norms, 0.95))
    from repro.core import support_from_rows
    sup = support_from_rows(res.beta_u.T, Lam)
    B = (res.beta_u * sup[None, :]).T
    return float(estimation_error(B, data.B)) / math.sqrt(m)


def main(n_runs: int = 6, out_dir: str = "experiments/paper"):
    t0 = time.time()
    ns = (50, 100, 200)
    errs_n = []
    for n in ns:
        e = np.mean([_dsml_err(jax.random.PRNGKey(i * 31), 10, n)
                     for i in range(n_runs)])
        errs_n.append(float(e))
    # log-log slope vs n (theory: -1/2)
    slope_n = float(np.polyfit(np.log(ns), np.log(errs_n), 1)[0])

    ms = (2, 8, 24)
    errs_m = []
    for m in ms:
        e = np.mean([_dsml_err(jax.random.PRNGKey(i * 17 + 5), m, 80)
                     for i in range(n_runs)])
        errs_m.append(float(e))

    rec = {"ns": ns, "errs_vs_n": errs_n, "slope_vs_n": slope_n,
           "ms": ms, "normalized_errs_vs_m": errs_m,
           "m_transfer_benefit": errs_m[0] > errs_m[-1]}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "rates.json"), "w") as f:
        json.dump(rec, f, indent=2)
    dt = (time.time() - t0) * 1e6 / 6
    return [
        f"rates_slope_vs_n,{dt:.0f},{slope_n:.3f}(theory -0.5)",
        f"rates_err_m2,{dt:.0f},{errs_m[0]:.3f}",
        f"rates_err_m24,{dt:.0f},{errs_m[-1]:.3f}",
        f"rates_transfer_benefit,{dt:.0f},{rec['m_transfer_benefit']}",
    ]


if __name__ == "__main__":
    for r in main():
        print(r)
