"""Perf regression gate on the committed kernel benchmark JSON.

`make bench-json` writes BENCH_kernels.json (via `benchmarks/run.py
--json-out`); this script fails CI when a tracked speedup ratio drops
below its floor — the fused batched kernel must never be slower than
the vmap path it replaced, and the fused-momentum FISTA iteration must
never be slower than the two-op pair.

Usage:
    python benchmarks/check_regression.py [--current PATH]
                                          [--baseline PATH]

With only `--current` (default BENCH_kernels.json) the floors are
checked on that file — on the committed baseline this is deterministic.
With `--baseline` (e.g. the committed JSON from the previous PR) the
current speedups must also not collapse to less than `--max-drop`
(default 0.5) of the baseline's. When REGENERATING the JSON on a noisy
CPU box, the interpret-mode ratios carry ~10% run-to-run noise even
with the median-of-paired-ratios estimator: a sub-floor fused-over-vmap
on a fresh run means "re-run on a quiet machine", not necessarily a
kernel regression — the floor exists to keep a bad number from being
committed as the new baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

# (row name, floor for its `speedup` field). The fused-over-vmap parity
# is the hard 1.0x contract from the kernel's introduction; the two
# engine-v2 pairs compare near-identical interpret-mode computations
# whose CPU ratio is 1.0 +/- ~10% measurement noise, so their floors
# leave that margin (the TPU win — fewer dispatches/HBM trips — is not
# what CPU interpret mode measures).
FLOORS = (
    ("kernel_ista_batched_fused_over_vmap", 1.0),
    ("kernel_fista_fused_over_two_op", 0.85),
    ("logistic_solve_batched_over_vmap", 0.85),
    ("logistic_grad_fused_over_unfused", 0.85),
    # the feature-tiled large-p slab (p = 8192, past the old full-lane
    # cliff): fusion must keep paying for itself once the X stream is
    # two-phase — the unfused pair re-streams X from HBM AND round-trips
    # the residual
    ("logistic_grad_fused_over_unfused_p8192", 0.85),
    ("rank_update_fused_over_unfused", 0.85),
)


def _speedups(path: str) -> dict:
    with open(path) as f:
        rows = json.load(f)
    return {r["name"]: r["speedup"] for r in rows if "speedup" in r}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_kernels.json")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--max-drop", type=float, default=0.5,
                    help="min allowed current/baseline speedup ratio")
    args = ap.parse_args()

    cur = _speedups(args.current)
    failures = []
    for name, floor in FLOORS:
        if name not in cur:
            failures.append(f"{name}: missing from {args.current}")
        elif cur[name] < floor:
            failures.append(f"{name}: {cur[name]:.2f}x < floor {floor:.2f}x")
        else:
            print(f"ok {name}: {cur[name]:.2f}x (floor {floor:.2f}x)")

    if args.baseline:
        base = _speedups(args.baseline)
        for name, _ in FLOORS:
            if name in base and name in cur:
                ratio = cur[name] / base[name]
                if ratio < args.max_drop:
                    failures.append(
                        f"{name}: {cur[name]:.2f}x is {ratio:.2f} of "
                        f"baseline {base[name]:.2f}x (< {args.max_drop})")

    for f in failures:
        print(f"REGRESSION {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
