"""Perf regression gate on the committed kernel benchmark JSON.

`make bench-json` writes BENCH_kernels.json (via `benchmarks/run.py
--json-out`); this script fails CI when a tracked speedup ratio drops
below its floor — the fused batched kernel must never be slower than
the vmap path it replaced, and the fused-momentum FISTA iteration must
never be slower than the two-op pair. `make bench-serve-smoke` writes
BENCH_serve.json the same way; its serving-front rows are gated by
`SERVE_BOUNDS` (request p99 ceiling, ingest-while-serving floor).

Usage:
    python benchmarks/check_regression.py [--current PATH]
                                          [--baseline PATH]

With only `--current` (default BENCH_kernels.json) the floors are
checked on that file — on the committed baseline this is deterministic.
With `--baseline` (e.g. the committed JSON from the previous PR) the
current speedups must also not collapse to less than `--max-drop`
(default 0.5) of the baseline's. When REGENERATING the JSON on a noisy
CPU box, the interpret-mode ratios carry ~10% run-to-run noise even
with the median-of-paired-ratios estimator: a sub-floor fused-over-vmap
on a fresh run means "re-run on a quiet machine", not necessarily a
kernel regression — the floor exists to keep a bad number from being
committed as the new baseline.
"""
from __future__ import annotations

import argparse
import json
import sys

# (row name, floor for its `speedup` field). The fused-over-vmap parity
# is the hard 1.0x contract from the kernel's introduction; the two
# engine-v2 pairs compare near-identical interpret-mode computations
# whose CPU ratio is 1.0 +/- ~10% measurement noise, so their floors
# leave that margin (the TPU win — fewer dispatches/HBM trips — is not
# what CPU interpret mode measures).
FLOORS = (
    ("kernel_ista_batched_fused_over_vmap", 1.0),
    ("kernel_fista_fused_over_two_op", 0.85),
    ("logistic_solve_batched_over_vmap", 0.85),
    ("logistic_grad_fused_over_unfused", 0.85),
    # the feature-tiled large-p slab (p = 8192, past the old full-lane
    # cliff): fusion must keep paying for itself once the X stream is
    # two-phase — the unfused pair re-streams X from HBM AND round-trips
    # the residual
    ("logistic_grad_fused_over_unfused_p8192", 0.85),
    ("rank_update_fused_over_unfused", 0.85),
)

# Bounds on the committed serving-front artifact (BENCH_serve.json,
# written by `make bench-serve-smoke`): (row name, field, kind, bound).
# "max" rows are latency ceilings, "min" rows are throughput floors.
# Margins are deliberately generous (~25x under the measured p99 of
# ~4-10ms, ~10x under the measured ~3000 rows/s): a shared CI worker is
# slow and noisy, and the gate exists to catch the serving front losing
# an order of magnitude — a torn microbatch loop, a sync landing on the
# admission path — not to chase scheduler jitter.
SERVE_BOUNDS = (
    ("stream_serve_p99_ms", "p99_ms", "max", 250.0),
    ("stream_ingest_while_serving", "rows_per_s", "min", 300.0),
    ("stream_ingest_while_serving", "p99_ms", "max", 500.0),
)


def check_serve_bounds(path: str) -> list:
    """Bound the serving-front rows of BENCH_serve.json; a missing file
    or row fails loudly (a stale gate is no gate)."""
    try:
        by_name = {r["name"]: r for r in _rows(path)}
    except FileNotFoundError:
        return [f"serve: {path} missing (run `make bench-serve-smoke`)"]
    failures = []
    for name, field, kind, bound in SERVE_BOUNDS:
        row = by_name.get(name)
        if row is None or field not in row:
            failures.append(f"serve {name}.{field}: missing from {path}")
            continue
        val = row[field]
        ok = val <= bound if kind == "max" else val >= bound
        if ok:
            print(f"ok serve {name}.{field}: {val:.2f} "
                  f"({kind} bound {bound:.2f})")
        else:
            failures.append(f"serve {name}.{field}: {val:.2f} violates "
                            f"{kind} bound {bound:.2f}")
    return failures


def _rows(path: str) -> list:
    """Benchmark rows from either artifact format: the PR-7+
    `{"meta": ..., "rows": [...]}` object or the older flat list."""
    with open(path) as f:
        data = json.load(f)
    return data["rows"] if isinstance(data, dict) else data


def _speedups(path: str) -> dict:
    return {r["name"]: r["speedup"] for r in _rows(path)
            if "speedup" in r}


# calls a single instrumented dispatch makes with telemetry disabled:
# a generous ceiling over any real code path (the logistic solve makes
# one record_route per compilation plus one engine record per call)
OBS_CALLS_PER_DISPATCH = 16


def check_obs_overhead(current: str, budget: float = 0.02) -> list:
    """Guard the REPRO_OBS=0 path: time disabled-mode no-op telemetry
    calls and require `OBS_CALLS_PER_DISPATCH` of them to cost under
    `budget` (2%) of every tracked kernel pair's per-call time. Keeps
    instrumentation honest — the disabled registry must stay a single
    attribute check, never grow a lock acquisition or dict lookup."""
    import time
    try:
        from repro.obs.registry import Registry
    except ImportError:
        print("skip obs_overhead: repro.obs not importable "
              "(run with PYTHONPATH=src)")
        return []
    reg = Registry(enabled=False)
    N = 200_000
    t0 = time.perf_counter()
    for _ in range(N):
        reg.inc("overhead.probe", kernel="x", outcome="y")
    t_inc = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(N):
        with reg.span("overhead.probe", kernel="x"):
            pass
    t_span = time.perf_counter() - t0
    per_call_us = max(t_inc, t_span) / N * 1e6
    overhead_us = OBS_CALLS_PER_DISPATCH * per_call_us
    failures = []
    by_name = {r["name"]: r for r in _rows(current)}
    for name, _ in FLOORS:
        row = by_name.get(name)
        if row is None or not row.get("us"):
            continue
        frac = overhead_us / row["us"]
        if frac > budget:
            failures.append(
                f"obs_overhead {name}: {overhead_us:.2f}us disabled-mode "
                f"telemetry is {frac:.1%} of {row['us']:.0f}us "
                f"(> {budget:.0%})")
    if not failures:
        print(f"ok obs_overhead: {OBS_CALLS_PER_DISPATCH} disabled calls "
              f"= {overhead_us:.2f}us (< {budget:.0%} of every tracked "
              f"pair)")
    return failures


def check_guard_overhead(budget: float = 0.02) -> list:
    """Gate guarded ingest at < `budget` (2%) over the unguarded fold.

    Times the same ingest stream through two services — `guard=False`
    vs the default `IngestGuard` — with a `block_until_ready` per chunk
    on BOTH paths, so the async fold dispatch cannot hide (or fake) the
    guard's per-chunk device sync. The probe is O(m·n·p) in front of an
    O(m·n·p²) fold (~1/p relative), so at serving shapes the budget has
    an order of magnitude of headroom; the gate exists to catch the
    probe growing a second dispatch or a host-side recompute. Best of 3
    paired repeats damps CPU timer noise.
    """
    import time
    try:
        import jax
        import numpy as np
        from repro.stream import StreamingDsmlService
    except ImportError:
        print("skip guard_overhead: jax/repro not importable "
              "(run with PYTHONPATH=src)")
        return []
    m, n, p, iters = 4, 512, 256, 20
    rng = np.random.default_rng(0)
    X = jax.numpy.asarray(rng.standard_normal((m, n, p)),
                          jax.numpy.float32)
    y = jax.numpy.asarray(rng.standard_normal((m, n)), jax.numpy.float32)

    def run(guard) -> float:
        svc = StreamingDsmlService(m, p, lam=0.4, mu=0.2, Lam=1.0,
                                   refit_every=10**9, guard=guard,
                                   refit_health_checks=False)
        for _ in range(3):      # warm the jit caches outside the clock
            svc.ingest(X, y)
            jax.block_until_ready(svc.state.Sigmas)
        t0 = time.perf_counter()
        for _ in range(iters):
            svc.ingest(X, y)
            jax.block_until_ready(svc.state.Sigmas)
        return time.perf_counter() - t0

    frac = min(run(True) / run(False) for _ in range(3)) - 1.0
    if frac > budget:
        return [f"guard_overhead: guarded ingest is {frac:+.1%} vs "
                f"unguarded (> {budget:.0%}) at (m={m}, n={n}, p={p})"]
    print(f"ok guard_overhead: {frac:+.1%} vs unguarded "
          f"(budget {budget:.0%}) at (m={m}, n={n}, p={p})")
    return []


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_kernels.json")
    ap.add_argument("--serve", default="BENCH_serve.json",
                    help="serving-front artifact for SERVE_BOUNDS")
    ap.add_argument("--baseline", default=None)
    ap.add_argument("--max-drop", type=float, default=0.5,
                    help="min allowed current/baseline speedup ratio")
    args = ap.parse_args()

    cur = _speedups(args.current)
    failures = []
    for name, floor in FLOORS:
        if name not in cur:
            failures.append(f"{name}: missing from {args.current}")
        elif cur[name] < floor:
            failures.append(f"{name}: {cur[name]:.2f}x < floor {floor:.2f}x")
        else:
            print(f"ok {name}: {cur[name]:.2f}x (floor {floor:.2f}x)")

    if args.baseline:
        base = _speedups(args.baseline)
        for name, _ in FLOORS:
            if name in base and name in cur:
                ratio = cur[name] / base[name]
                if ratio < args.max_drop:
                    failures.append(
                        f"{name}: {cur[name]:.2f}x is {ratio:.2f} of "
                        f"baseline {base[name]:.2f}x (< {args.max_drop})")

    failures.extend(check_serve_bounds(args.serve))
    failures.extend(check_obs_overhead(args.current))
    failures.extend(check_guard_overhead())

    for f in failures:
        print(f"REGRESSION {f}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
