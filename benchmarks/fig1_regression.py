"""Paper Figure 1: multi-task regression, p=200, s=10, sigma=1.

Top row:    m=10 fixed, n varied.
Bottom row: n=50 fixed, m varied.
Metrics: Hamming distance, l1/l2 estimation error, prediction error.
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.paper_common import average_runs, eval_regression_methods
from repro.core import gen_regression

P, S_TRUE = 200, 10


def sweep(n_runs: int = 10):
    results = {"vary_n": {}, "vary_m": {}}
    for n in (30, 50, 80, 120):
        results["vary_n"][n] = average_runs(
            lambda key: eval_regression_methods(
                gen_regression(key, m=10, n=n, p=P, s=S_TRUE)),
            n_runs)
    for m in (2, 5, 10, 20):
        results["vary_m"][m] = average_runs(
            lambda key: eval_regression_methods(
                gen_regression(key, m=m, n=50, p=P, s=S_TRUE)),
            n_runs)
    return results


def main(n_runs: int = 10, out_dir: str = "experiments/paper"):
    t0 = time.time()
    results = sweep(n_runs)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig1_regression.json"), "w") as f:
        json.dump(results, f, indent=2)
    dt = time.time() - t0
    rows = []
    for sweep_name, pts in results.items():
        for x, methods in pts.items():
            for meth, met in methods.items():
                rows.append(
                    f"fig1_{sweep_name}_{x}_{meth},"
                    f"{dt * 1e6 / max(len(rows), 1):.0f},"
                    f"hamming={met['hamming']:.2f};est={met['est_err']:.2f};"
                    f"pred={met['pred_err']:.4f}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
