"""Paper Figure 1: multi-task regression, p=200, s=10, sigma=1.

Top row:    m=10 fixed, n varied.
Bottom row: n=50 fixed, m varied.
Metrics: Hamming distance, l1/l2 estimation error, prediction error.

The tuned local-lasso baseline inside `eval_regression_methods` runs its
whole lambda-grid x tasks sweep as one batched sufficient-statistics
engine call (see core/engine.solve_lasso_grid); `--smoke` shrinks the
sweep to a single run per point for the CI bench-smoke job.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.paper_common import average_runs, eval_regression_methods
from repro.core import gen_regression

P, S_TRUE = 200, 10


VARY_N = (30, 50, 80, 120)
VARY_M = (2, 5, 10, 20)


def sweep(n_runs: int = 10, *, iters: int = 400, vary_n=VARY_N,
          vary_m=VARY_M):
    """`vary_n` / `vary_m` select the sweep points (paper defaults);
    the golden smoke test drives one point per sweep through this exact
    code path."""
    results = {"vary_n": {}, "vary_m": {}}
    for n in vary_n:
        results["vary_n"][n] = average_runs(
            lambda key: eval_regression_methods(
                gen_regression(key, m=10, n=n, p=P, s=S_TRUE), iters=iters),
            n_runs)
    for m in vary_m:
        results["vary_m"][m] = average_runs(
            lambda key: eval_regression_methods(
                gen_regression(key, m=m, n=50, p=P, s=S_TRUE), iters=iters),
            n_runs)
    return results


def main(n_runs: int = 10, out_dir: str = "experiments/paper", *,
         iters: int = 400, vary_n=VARY_N, vary_m=VARY_M):
    t0 = time.time()
    results = sweep(n_runs, iters=iters, vary_n=vary_n, vary_m=vary_m)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig1_regression.json"), "w") as f:
        json.dump(results, f, indent=2)
    dt = time.time() - t0
    rows = []
    for sweep_name, pts in results.items():
        for x, methods in pts.items():
            for meth, met in methods.items():
                rows.append(
                    f"fig1_{sweep_name}_{x}_{meth},"
                    f"{dt * 1e6 / max(len(rows), 1):.0f},"
                    f"hamming={met['hamming']:.2f};est={met['est_err']:.2f};"
                    f"pred={met['pred_err']:.4f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="1 run per point with a reduced iteration budget")
    args = ap.parse_args()
    n_runs = 1 if args.smoke else args.runs
    for r in main(n_runs, iters=200 if args.smoke else 400):
        print(r)
