"""Kernel microbenchmarks.

On this CPU container, interpret-mode timings measure the Python
emulation (NOT TPU perf) — reported for completeness; `derived` carries
the analytic FLOPs per call, which is the number the TPU roofline uses.
The jnp reference path is timed as the XLA-CPU baseline.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.paper_common import time_fn as _time
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.group_threshold.ref import group_threshold_ref
from repro.kernels.ista_step.ops import (
    fista_step_batched, ista_step, ista_step_batched,
)
from repro.kernels.ista_step.ref import ista_step_batched_ref, ista_step_ref
from repro.kernels.logistic_grad.ops import logistic_grad, logistic_grad_unfused
from repro.kernels.logistic_grad.ref import logistic_grad_ref
from repro.kernels.rank_update.ops import rank_update, rank_update_unfused
from repro.kernels.rank_update.ref import rank_update_ref


def _interleaved_pair(fa, fb, *args, reps: int = 2, rounds: int = 5):
    """Drift-robust pairing: interpret-mode emulation speed drifts
    within a process, so interleave the two paths (the original
    min-of-2 pattern, widened to `rounds`) and report min-time per path
    plus the MEDIAN of the per-round a-vs-b ratios — adjacent
    measurements see the same drift, so the paired ratio cancels it
    where a ratio of independent minima does not."""
    ta, tb = [], []
    for _ in range(rounds):
        ta.append(_time(fa, *args, reps=reps))
        tb.append(_time(fb, *args, reps=reps))
    ratios = sorted(b / a for a, b in zip(ta, tb))
    return min(ta), min(tb), ratios[len(ratios) // 2]


def main():
    rows = []
    key = jax.random.PRNGKey(0)

    # ista_step: p=512, r=512 (the M-matrix solve shape for p=512)
    p = r = 512
    A = jax.random.normal(key, (p, p))
    Sigma = A @ A.T / p
    beta = jax.random.normal(key, (p, r))
    c = jax.random.normal(key, (p, r))
    f = jax.jit(lambda S, b, cc: ista_step_ref(S, b, cc, 0.01, 0.1))
    us = _time(f, Sigma, beta, c)
    flops = 2 * p * p * r
    rows.append(f"kernel_ista_step_p{p}_r{r},{us:.0f},flops={flops}")

    # batched lasso hot step (m=16 tasks, p=512): the engine's fused
    # multi-RHS pallas step vs the per-task vmap path, both in interpret
    # mode (the TPU BlockSpecs executed on CPU), plus the XLA batched
    # oracle that the engine uses as its CPU fast path.
    m = 16
    p = 512
    A = jax.random.normal(key, (m, p, p))
    Sigmas = jnp.einsum("tij,tkj->tik", A, A) / p
    B = jax.random.normal(jax.random.PRNGKey(1), (m, p, 1))
    C = jax.random.normal(jax.random.PRNGKey(2), (m, p, 1))
    etas = jnp.full((m,), 0.01)
    flops = 2 * m * p * p
    fused = jax.jit(lambda S, b, c: ista_step_batched(S, b, c, etas, 0.1,
                                                      interpret=True))
    vmapped = jax.jit(jax.vmap(
        lambda S, b, c: ista_step(S, b, c, 0.01, 0.1, interpret=True)))
    oracle = jax.jit(lambda S, b, c: ista_step_batched_ref(S, b, c, etas, 0.1))
    us_fused, us_vmap, r_fv = _interleaved_pair(fused, vmapped, Sigmas, B, C,
                                                rounds=7)
    us_ref = _time(oracle, Sigmas, B, C)
    rows.append(f"kernel_ista_batched_fused_m16_p512,{us_fused:.0f},flops={flops}")
    rows.append(f"kernel_ista_batched_vmap_m16_p512,{us_vmap:.0f},flops={flops}")
    rows.append(f"kernel_ista_batched_xla_ref_m16_p512,{us_ref:.0f},flops={flops}")
    rows.append(f"kernel_ista_batched_fused_over_vmap,{us_fused:.0f},"
                f"speedup={r_fv:.2f}x")

    # one full FISTA iteration (engine v2): the fused-momentum kernel
    # (prox + extrapolation in one dispatch) vs the historical two-op
    # path (ista kernel + separate jnp momentum pass), interpret mode
    X = jax.random.normal(jax.random.PRNGKey(4), (m, p, 1))
    theta = 0.6
    fista_fused = jax.jit(lambda S, z, x, c: fista_step_batched(
        S, z, x, c, etas, 0.1, theta, interpret=True))

    def _two_op(S, z, x, c):
        xn = ista_step_batched(S, z, c, etas, 0.1, interpret=True)
        return xn, xn + theta * (xn - x)
    two_op = jax.jit(_two_op)
    us_f, us_2, r_f2 = _interleaved_pair(fista_fused, two_op, Sigmas, B, X, C)
    rows.append(f"kernel_fista_fused_m16_p512,{us_f:.0f},flops={flops}")
    rows.append(f"kernel_fista_two_op_m16_p512,{us_2:.0f},flops={flops}")
    rows.append(f"kernel_fista_fused_over_two_op,{us_f:.0f},"
                f"speedup={r_f2:.2f}x")

    # batched logistic solve (engine v2): one all-tasks einsum FISTA
    # loop vs the per-task vmap(fista) path it replaced (m=16, p=512)
    from repro.core.engine import solve_logistic_lasso_batched
    from repro.core.prox import soft_threshold
    from repro.core.solvers import fista, power_iteration
    n_log, iters_log = 128, 30
    Xs = jax.random.normal(jax.random.PRNGKey(5), (m, n_log, p))
    ys = jnp.sign(jax.random.normal(jax.random.PRNGKey(6), (m, n_log)))

    def _per_task(X, y):
        Sg = (X.T @ X) / n_log
        step = 1.0 / jnp.maximum(0.25 * power_iteration(Sg), 1e-12)

        def grad(b):
            z = X @ b
            return -(X.T @ (y * jax.nn.sigmoid(-y * z))) / n_log

        prox = lambda v, s: soft_threshold(v, s * 0.05)
        return fista(grad, prox, jnp.zeros(p, X.dtype), step, iters_log)

    batched = jax.jit(lambda X, y: solve_logistic_lasso_batched(
        X, y, 0.05, iters=iters_log))
    vmap_log = jax.jit(jax.vmap(_per_task))
    us_b, us_v, r_bv = _interleaved_pair(batched, vmap_log, Xs, ys)
    flops_log = 4 * m * n_log * p * iters_log       # fwd + bwd einsum per iter
    rows.append(f"logistic_solve_batched_m16_p512,{us_b:.0f},flops={flops_log}")
    rows.append(f"logistic_solve_vmap_m16_p512,{us_v:.0f},flops={flops_log}")
    rows.append(f"logistic_solve_batched_over_vmap,{us_b:.0f},"
                f"speedup={r_bv:.2f}x")

    # fused logistic-gradient kernel (engine hot path for every
    # Section-4 solve): one dispatch computing X@b, the sigmoid
    # residual, and the X'r back-projection from the same resident
    # tiles, vs the unfused two-dispatch pallas pair (forward matvec
    # kernel + jnp residual + back-projection kernel), both interpret
    # mode; the XLA einsum oracle (the engine's CPU fast path) for
    # context
    n_g = 128
    Xg = jax.random.normal(jax.random.PRNGKey(7), (m, n_g, p))
    yg = jnp.sign(jax.random.normal(jax.random.PRNGKey(8), (m, n_g)))
    Bg = jax.random.normal(jax.random.PRNGKey(9), (m, p)) * 0.1
    g_fused = jax.jit(lambda X, y, b: logistic_grad(X, y, b, interpret=True))
    g_unfused = jax.jit(lambda X, y, b: logistic_grad_unfused(
        X, y, b, interpret=True))
    g_ref = jax.jit(logistic_grad_ref)
    us_gf, us_gu, r_gu = _interleaved_pair(g_fused, g_unfused, Xg, yg, Bg)
    us_gr = _time(g_ref, Xg, yg, Bg)
    flops_g = 4 * m * n_g * p          # fwd + bwd matvec
    rows.append(f"logistic_grad_fused_m16_p512,{us_gf:.0f},flops={flops_g}")
    rows.append(f"logistic_grad_unfused_m16_p512,{us_gu:.0f},flops={flops_g}")
    rows.append(f"logistic_grad_xla_ref_m16_p512,{us_gr:.0f},flops={flops_g}")
    rows.append(f"logistic_grad_fused_over_unfused,{us_gf:.0f},"
                f"speedup={r_gu:.2f}x")

    # feature-tiled large-p slab (DESIGN.md §12): p = 8192 is past the
    # old full-lane cliff that routed every large-p gradient to the
    # oracle; the two-phase fused sweep vs the unfused pair at the same
    # budgeted (bn, bp) tiling, XLA einsum oracle for context
    from repro.kernels.logistic_grad.ops import (
        resolve_logistic_blocks, routes_to_oracle,
    )
    m_l, n_l, p_l = 4, 128, 8192
    assert not routes_to_oracle(n_l, p_l), "large-p must stay on-kernel"
    bn_l, bp_l = resolve_logistic_blocks(n_l, p_l)
    Xl = jax.random.normal(jax.random.PRNGKey(12), (m_l, n_l, p_l))
    yl = jnp.sign(jax.random.normal(jax.random.PRNGKey(13), (m_l, n_l)))
    Bl = jax.random.normal(jax.random.PRNGKey(14), (m_l, p_l)) * 0.02
    # g_fused/g_unfused/g_ref from the p=512 pair are shape-generic
    us_lf, us_lu, r_lu = _interleaved_pair(g_fused, g_unfused, Xl, yl, Bl)
    us_lr = _time(g_ref, Xl, yl, Bl, reps=3)
    flops_l = 4 * m_l * n_l * p_l
    rows.append(f"logistic_grad_fused_m4_n128_p8192,{us_lf:.0f},"
                f"flops={flops_l},bn={bn_l},bp={bp_l}")
    rows.append(f"logistic_grad_unfused_m4_n128_p8192,{us_lu:.0f},"
                f"flops={flops_l}")
    rows.append(f"logistic_grad_xla_ref_m4_n128_p8192,{us_lr:.0f},"
                f"flops={flops_l}")
    rows.append(f"logistic_grad_fused_over_unfused_p8192,{us_lf:.0f},"
                f"speedup={r_lu:.2f}x")

    # fused rank-n statistics update (streaming ingest hot path): Sigma
    # and c from ONE pass over the sample chunk vs the unfused
    # two-dispatch pair (covariance kernel + correlation kernel, X
    # streamed twice), interpret mode; XLA einsum oracle for context
    m_r, n_r, p_r = 8, 512, 256
    Xr = jax.random.normal(jax.random.PRNGKey(10), (m_r, n_r, p_r))
    yr = jax.random.normal(jax.random.PRNGKey(11), (m_r, n_r))
    r_fused = jax.jit(lambda X, y: rank_update(X, y, interpret=True,
                                               use_kernel=True))
    r_unfused = jax.jit(lambda X, y: rank_update_unfused(X, y,
                                                         interpret=True))
    r_ref = jax.jit(lambda X, y: rank_update_ref(X, y))
    us_rf, us_ru, r_ru = _interleaved_pair(r_fused, r_unfused, Xr, yr)
    us_rr = _time(r_ref, Xr, yr)
    flops_r = 2 * m_r * n_r * p_r * (p_r + 1)
    rows.append(f"rank_update_fused_m8_n512_p256,{us_rf:.0f},flops={flops_r}")
    rows.append(f"rank_update_unfused_m8_n512_p256,{us_ru:.0f},"
                f"flops={flops_r}")
    rows.append(f"rank_update_xla_ref_m8_n512_p256,{us_rr:.0f},"
                f"flops={flops_r}")
    rows.append(f"rank_update_fused_over_unfused,{us_rf:.0f},"
                f"speedup={r_ru:.2f}x")

    # streaming ingest: the always-on rank-n update of the stream layer
    # (one chunk of m=16 tasks x n=1024 rows into p=256 running stats)
    from repro.stream import ingest, init_stream_state
    m, n, p = 16, 1024, 256
    state = init_stream_state(m, p)
    Xb = jax.random.normal(key, (m, n, p))
    yb = jax.random.normal(jax.random.PRNGKey(3), (m, n))
    us = _time(ingest, state, Xb, yb)
    flops = 2 * m * n * p * p
    rows.append(f"stream_ingest_m{m}_n{n}_p{p},{us:.0f},flops={flops},"
                f"rows_per_s={m * n / (us * 1e-6):.0f}")

    # group_threshold: p=200000 rows x m=16
    B = jax.random.normal(key, (200_000, 16))
    f = jax.jit(lambda b: group_threshold_ref(b, 2.0))
    us = _time(f, B)
    rows.append(f"kernel_group_threshold_200k_x16,{us:.0f},bytes={B.size * 4}")

    # flash attention fwd: S=2048, 8 heads, H=64
    q = jax.random.normal(key, (1, 2048, 8, 64), jnp.float32)
    k = jax.random.normal(key, (1, 2048, 8, 64), jnp.float32)
    v = jax.random.normal(key, (1, 2048, 8, 64), jnp.float32)
    f = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v, causal=True))
    us = _time(f, q, k, v, reps=5)
    flops = 4 * 2048 * 2048 * 8 * 64  # qk + pv
    rows.append(f"kernel_flash_attn_s2048_h8,{us:.0f},flops={flops}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
