"""Paper Figure 2: multi-task classification (logistic), p=200, s=10.

Top row:    m=10 fixed, n varied.
Bottom row: n=150 fixed, m varied.
Prediction error is the held-out 0/1 error (fresh data per run).

`--smoke` shrinks the sweep to one run per point with a reduced
iteration budget (the CI bench job and the golden smoke test use it).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax

from benchmarks.paper_common import average_runs, eval_classification_methods
from repro.core import gen_classification

P, S_TRUE = 200, 10
VARY_N = (80, 150, 250)
VARY_M = (3, 10, 20)


def _one(key, m, n, iters):
    k1, k2 = jax.random.split(key)
    data = gen_classification(k1, m=m, n=n, p=P, s=S_TRUE)
    test = gen_classification(k2, m=m, n=500, p=P, s=S_TRUE)
    test = test._replace(ys=jax.numpy.sign(
        jax.numpy.einsum("tnp,pt->tn", test.Xs, data.B)))
    return eval_classification_methods(data, test, iters=iters)


def sweep(n_runs: int = 8, *, iters: int = 500, vary_n=VARY_N,
          vary_m=VARY_M):
    """`vary_n` / `vary_m` select the sweep points (paper defaults);
    the golden smoke test drives one point per sweep through this exact
    code path."""
    results = {"vary_n": {}, "vary_m": {}}
    for n in vary_n:
        results["vary_n"][n] = average_runs(
            lambda key: _one(key, 10, n, iters), n_runs)
    for m in vary_m:
        results["vary_m"][m] = average_runs(
            lambda key: _one(key, m, 150, iters), n_runs)
    return results


def main(n_runs: int = 8, out_dir: str = "experiments/paper", *,
         iters: int = 500, vary_n=VARY_N, vary_m=VARY_M):
    t0 = time.time()
    results = sweep(n_runs, iters=iters, vary_n=vary_n, vary_m=vary_m)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2_classification.json"), "w") as f:
        json.dump(results, f, indent=2)
    dt = time.time() - t0
    rows = []
    for sweep_name, pts in results.items():
        for x, methods in pts.items():
            for meth, met in methods.items():
                rows.append(
                    f"fig2_{sweep_name}_{x}_{meth},"
                    f"{dt * 1e6 / 36:.0f},"
                    f"hamming={met['hamming']:.2f};est={met['est_err']:.2f};"
                    f"pred={met['pred_err']:.4f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--runs", type=int, default=8)
    ap.add_argument("--smoke", action="store_true",
                    help="1 run per point with a reduced iteration budget")
    args = ap.parse_args()
    n_runs = 1 if args.smoke else args.runs
    for r in main(n_runs, iters=250 if args.smoke else 500):
        print(r)
