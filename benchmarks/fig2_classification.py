"""Paper Figure 2: multi-task classification (logistic), p=200, s=10.

Top row:    m=10 fixed, n varied.
Bottom row: n=150 fixed, m varied.
Prediction error is the held-out 0/1 error (fresh data per run).
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.paper_common import average_runs, eval_classification_methods
from repro.core import gen_classification

P, S_TRUE = 200, 10


def _one(key, m, n):
    k1, k2 = jax.random.split(key)
    data = gen_classification(k1, m=m, n=n, p=P, s=S_TRUE)
    test = gen_classification(k2, m=m, n=500, p=P, s=S_TRUE)
    test = test._replace(ys=jax.numpy.sign(
        jax.numpy.einsum("tnp,pt->tn", test.Xs, data.B)))
    return eval_classification_methods(data, test)


def sweep(n_runs: int = 8):
    results = {"vary_n": {}, "vary_m": {}}
    for n in (80, 150, 250):
        results["vary_n"][n] = average_runs(
            lambda key: _one(key, 10, n), n_runs)
    for m in (3, 10, 20):
        results["vary_m"][m] = average_runs(
            lambda key: _one(key, m, 150), n_runs)
    return results


def main(n_runs: int = 8, out_dir: str = "experiments/paper"):
    t0 = time.time()
    results = sweep(n_runs)
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "fig2_classification.json"), "w") as f:
        json.dump(results, f, indent=2)
    dt = time.time() - t0
    rows = []
    for sweep_name, pts in results.items():
        for x, methods in pts.items():
            for meth, met in methods.items():
                rows.append(
                    f"fig2_{sweep_name}_{x}_{meth},"
                    f"{dt * 1e6 / 36:.0f},"
                    f"hamming={met['hamming']:.2f};est={met['est_err']:.2f};"
                    f"pred={met['pred_err']:.4f}")
    return rows


if __name__ == "__main__":
    for r in main():
        print(r)
