"""Roofline report: reads the dry-run artifacts (experiments/dryrun/) and
prints/persists the per-(arch x shape x mesh) three-term table used by
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os


def load_records(path: str = None):
    if path is None:
        import os
        path = "experiments/dryrun_final" if os.path.isdir(
            "experiments/dryrun_final") else "experiments/dryrun"
    recs = []
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        recs.append(json.load(open(f)))
    return recs


def table(recs, mesh: str = "16x16"):
    rows = []
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r["status"] != "ok":
            rows.append((r["arch"], r["shape"], r["status"], "-", "-", "-",
                         "-", "-", r.get("note", "")))
            continue
        rf = r["roofline"]
        rows.append((
            r["arch"], r["shape"], rf["bottleneck"],
            f"{rf['compute_s']:.4f}", f"{rf['memory_s']:.4f}",
            f"{rf['collective_s']:.4f}", f"{r['useful_ratio']:.2f}",
            f"{r['model_flops']:.3e}", r.get("note", "")))
    return rows


def main():
    recs = load_records()
    out = []
    for mesh in ("16x16", "2x16x16"):
        n_ok = sum(1 for r in recs if r.get("mesh") == mesh
                   and r["status"] == "ok")
        n_skip = sum(1 for r in recs if r.get("mesh") == mesh
                     and r["status"] == "skipped")
        out.append(f"dryrun_{mesh}_ok,{0},{n_ok}")
        out.append(f"dryrun_{mesh}_skipped,{0},{n_skip}")
    for arch, shape, bott, c, m, coll, ur, mf, note in table(recs):
        out.append(f"roofline_{arch}_{shape},{0},"
                   f"bottleneck={bott};compute={c};memory={m};"
                   f"collective={coll};useful={ur}")
    return out


if __name__ == "__main__":
    for r in main():
        print(r)
