"""Chaos driver: run a seeded fault schedule against a live service.

Drives every scriptable fault class from `repro.testing.faults` through
one `StreamingDsmlService` and asserts the resilience invariants the
chaos tier pins (ISSUE/DESIGN.md §15):

* the service NEVER serves a non-finite prediction;
* the generation NEVER regresses except by an explicit `restore()`;
* poisoned chunks leave `(Sigma, c)` bitwise unchanged (quarantined);
* forced refit divergence rolls back to the last good generation;
* truncating the checkpoint head still restarts from generation K-1.

Deterministic by construction: the run is a pure function of --seed.

    PYTHONPATH=src python tools/chaos.py --seed 7 --steps 24
    make test-chaos     # the pytest tier around the same machinery

Exit 0 when every invariant held, 1 with a FAIL report otherwise.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile

import numpy as np

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, os.path.join(os.path.dirname(_HERE), "src"))


def run_schedule(seed: int = 7, steps: int = 24, m: int = 4, p: int = 32,
                 n: int = 64, refit_every: int = 128,
                 ckpt_dir: str | None = None) -> dict:
    """One chaos run. Returns a report dict with `failures: [...]`."""
    import jax.numpy as jnp

    from repro.stream import StreamingDsmlService
    from repro.testing import (
        DivergenceInjector, apply_batch_fault, build_schedule,
        make_clean_batch, truncate_file,
    )

    from repro.stream.guard import IngestGuard

    rng = np.random.default_rng(seed)
    # first two steps guaranteed clean so the outlier gate has a
    # reference scale; warmup_chunks=1 arms it after one accepted chunk
    schedule = build_schedule(steps, seed, per_kind=2, start=2)
    svc = StreamingDsmlService(m, p, lam=0.4, mu=0.2, Lam=1.0,
                               refit_every=refit_every,
                               guard=IngestGuard(warmup_chunks=1),
                               ckpt_dir=ckpt_dir, ckpt_keep=3)
    inj = DivergenceInjector(svc)
    failures: list = []
    last_generation = 0
    clean_steps = poisoned_steps = 0

    # -- fault classes 1-3: poisoned batches; class 4: forced divergence
    for step in range(steps):
        X, y = make_clean_batch(rng, m, n, p)
        X_clean = X
        kind = schedule.fault_for(step)
        if kind is not None:
            X, y = apply_batch_fault(X, y, kind, rng)
            poisoned_steps += 1
            before = (np.asarray(svc.state.Sigmas).copy(),
                      np.asarray(svc.state.cs).copy())
        else:
            clean_steps += 1
            before = None
        # arm one forced divergence right before the refit threshold
        # trips, so the rollback path fires mid-schedule
        if step == steps // 2 and inj.injected == 0:
            inj.arm(1)
        svc.ingest(X, y)
        if before is not None:
            after = (np.asarray(svc.state.Sigmas), np.asarray(svc.state.cs))
            if not (np.array_equal(before[0], after[0], equal_nan=True)
                    and np.array_equal(before[1], after[1], equal_nan=True)):
                failures.append(f"step {step}: poisoned '{kind}' chunk "
                                f"mutated (Sigma, c)")
        gen = svc.generation
        if gen < last_generation:
            failures.append(f"step {step}: generation regressed "
                            f"{last_generation} -> {gen}")
        last_generation = gen
        pred = np.asarray(svc.predict(X_clean[:, :4, :]))
        if not np.isfinite(pred).all():
            failures.append(f"step {step}: served a non-finite prediction")

    if svc.guard.total_quarantined != poisoned_steps:
        failures.append(f"guard quarantined {svc.guard.total_quarantined} "
                        f"of {poisoned_steps} poisoned chunks")
    if inj.injected == 0:
        failures.append("divergence injector never fired (schedule too "
                        "short for the refit cadence?)")
    elif svc.rollbacks < inj.injected:
        failures.append(f"{inj.injected} forced divergences but only "
                        f"{svc.rollbacks} rollbacks")
    inj.uninstall()

    # -- fault class 5: torn checkpoint head, restart from K-1
    report_restore = None
    if ckpt_dir is not None and svc.ckpt_store is not None:
        gens = svc.ckpt_store.generations()
        if len(gens) < 2:
            svc.checkpoint()    # ensure at least two retained generations
            svc.state = svc.state._replace(
                generation=svc.state.generation + 1)
            svc.checkpoint()
            gens = svc.ckpt_store.generations()
        head = os.path.join(ckpt_dir, f"ckpt_{gens[0]:08d}.npz")
        truncate_file(head, keep_fraction=0.3)
        restored = svc.restore()
        report_restore = {"retained": gens, "restored": restored}
        if restored != gens[1]:
            failures.append(f"truncated head gen {gens[0]}: restored "
                            f"{restored}, wanted fallback to {gens[1]}")
        if not np.isfinite(np.asarray(svc.state.beta_tilde)).all():
            failures.append("restored model is non-finite")

    return {
        "seed": seed, "steps": steps,
        "clean": clean_steps, "poisoned": poisoned_steps,
        "schedule": schedule.by_kind(),
        "quarantine": svc.guard.summary(),
        "generation": svc.generation,
        "rollbacks": svc.rollbacks,
        "divergences_injected": inj.injected,
        "restore": report_restore,
        "failures": failures,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=7)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--m", type=int, default=4)
    ap.add_argument("--p", type=int, default=32)
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--refit-every", type=int, default=128)
    ap.add_argument("--json", action="store_true",
                    help="emit the full report as JSON")
    args = ap.parse_args(argv)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        report = run_schedule(seed=args.seed, steps=args.steps, m=args.m,
                              p=args.p, n=args.n,
                              refit_every=args.refit_every,
                              ckpt_dir=ckpt_dir)
    if args.json:
        print(json.dumps(report, indent=2, default=str))
    else:
        print(f"chaos seed={report['seed']} steps={report['steps']} "
              f"(poisoned {report['poisoned']}): "
              f"gen={report['generation']} rollbacks={report['rollbacks']} "
              f"quarantined={report['quarantine']['quarantined']} "
              f"restore={report['restore']}")
    if report["failures"]:
        for f in report["failures"]:
            print(f"FAIL: {f}")
        return 1
    print("all resilience invariants held")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
