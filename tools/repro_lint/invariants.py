"""Engine 1: AST invariant lints over `src/` and `benchmarks/`.

Pure stdlib — importing this module (and running every check in it)
never imports jax, so `make lint` stays fast and the `--cache` CLI mode
stays jax-free. Each check enforces one standing invariant from
ROADMAP.md; the finding codes are documented in DESIGN.md §13.

The checks are deliberately *named-pattern* lints, not a general type
system: they encode the specific conventions this repo already holds
itself to (substrate-only distribution plumbing, kernel-only pallas,
validated + routed dispatchers, namespaced autotune keys) and the
specific hazard classes that have actually bitten (silent `block=`
coercion, bare cache keys, tracer leaks).
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, Iterable, List, Set

from tools.repro_lint.findings import Finding

# --- path classification -------------------------------------------------

SUBSTRATE_RE = re.compile(r"(^|/)substrate/")
KERNEL_FILE_RE = re.compile(r"(^|/)kernels/[^/]+/kernel\.py$")
OPS_FILE_RE = re.compile(r"(^|/)kernels/[^/]+/ops\.py$")

# files allowed to mutate jax.config (none in src/benchmarks today;
# extend deliberately, with a DESIGN.md §13 note, never casually)
CONFIG_ALLOWLIST: Set[str] = set()

# --- RL101: substrate-only distribution plumbing -------------------------

# canonical dotted names that constitute shard_map / mesh / collective
# plumbing; jax.sharding TYPE imports (Mesh, PartitionSpec,
# NamedSharding) are deliberately NOT here — passing specs around is
# fine, creating meshes / mapping over them / communicating is not
_COLLECTIVES = ("psum", "pmean", "pmax", "pmin", "all_gather",
                "all_to_all", "ppermute", "pshuffle", "psum_scatter",
                "axis_index")
FORBIDDEN_PLUMBING = {
    "jax.shard_map", "jax.make_mesh", "jax.set_mesh",
    "jax.experimental.shard_map", "jax.experimental.mesh_utils",
    "jax.sharding.use_mesh",
} | {f"jax.lax.{c}" for c in _COLLECTIVES}

# --- RL102: kernel-only pallas -------------------------------------------

PALLAS_PREFIX = "jax.experimental.pallas"

# --- RL103/RL104: dispatcher convention ----------------------------------

PREDICATE_RE = re.compile(r"(^|_)is_ragged|routes_to_oracle$")
VALIDATOR_NAME = "validate_block"
PALLAS_CALLEE_RE = re.compile(r"_pallas$")

# --- RL105: namespaced autotune keys -------------------------------------

CACHE_DICT_RE = re.compile(r"^(_memory_cache|disk)$")

# --- RL107: tracer hazards -----------------------------------------------

TRACED_MODULE_PREFIXES = ("jax.numpy.", "jax.nn.", "jax.lax.",
                          "jax.random.", "jax.scipy.")
CAST_NAMES = {"float", "int", "bool"}


def dotted_name(node: ast.AST) -> str | None:
    """`a.b.c` attribute chain -> "a.b.c"; None for anything fancier."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class ModuleLint:
    """One parsed file plus the import-alias map the checks share."""

    def __init__(self, path: Path, rel: str, tree: ast.Module):
        self.path = path
        self.rel = rel
        self.tree = tree
        self.findings: List[Finding] = []
        # local alias -> canonical dotted module/name path
        self.aliases: Dict[str, str] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    self.aliases[a.asname or a.name.split(".")[0]] = (
                        a.name if a.asname else a.name.split(".")[0])
            elif isinstance(node, ast.ImportFrom) and node.module \
                    and node.level == 0:
                for a in node.names:
                    self.aliases[a.asname or a.name] = \
                        f"{node.module}.{a.name}"

    def canonical(self, node: ast.AST) -> str | None:
        """Dotted name with its leading alias resolved through the
        module's imports ("pl.pallas_call" -> "jax.experimental.pallas
        .pallas_call", "jnp.max" -> "jax.numpy.max")."""
        name = dotted_name(node)
        if name is None:
            return None
        head, _, rest = name.partition(".")
        full = self.aliases.get(head, head)
        return f"{full}.{rest}" if rest else full

    def flag(self, node: ast.AST, code: str, message: str) -> None:
        self.findings.append(
            Finding(self.rel, getattr(node, "lineno", 0), code, message))


# --- import boundaries (RL101, RL102) ------------------------------------

def _imported_names(node: ast.Import | ast.ImportFrom) -> Iterable[str]:
    if isinstance(node, ast.Import):
        for a in node.names:
            yield a.name
    elif node.module and node.level == 0:
        for a in node.names:
            yield f"{node.module}.{a.name}"


def check_import_boundaries(mod: ModuleLint) -> None:
    in_substrate = bool(SUBSTRATE_RE.search(mod.rel))
    in_kernel_file = bool(KERNEL_FILE_RE.search(mod.rel))
    for node in ast.walk(mod.tree):
        names: List[str] = []
        if isinstance(node, (ast.Import, ast.ImportFrom)):
            names = list(_imported_names(node))
        else:
            if isinstance(node, ast.Attribute):
                cname = mod.canonical(node)
                if cname:
                    names = [cname]
        for name in names:
            if not in_substrate and (
                    name in FORBIDDEN_PLUMBING
                    or any(name.startswith(f + ".")
                           for f in FORBIDDEN_PLUMBING)):
                mod.flag(node, "RL101",
                         f"'{name}' is substrate-only plumbing — route it "
                         f"through repro.substrate")
                break
            if not in_kernel_file and (
                    name == PALLAS_PREFIX
                    or name.startswith(PALLAS_PREFIX + ".")):
                mod.flag(node, "RL102",
                         f"'{name}' may only be imported by "
                         f"kernels/*/kernel.py")
                break


# --- dispatcher convention (RL103, RL104) --------------------------------

def _call_names(fn: ast.FunctionDef) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name:
                out.add(name.split(".")[-1])
    return out


def _reaches(name: str, calls: Dict[str, Set[str]],
             match) -> bool:
    """True when `name`'s transitive local call closure contains a
    callee whose (unqualified) name satisfies `match`."""
    seen: Set[str] = set()
    stack = [name]
    while stack:
        fn = stack.pop()
        if fn in seen:
            continue
        seen.add(fn)
        for callee in calls.get(fn, ()):
            if match(callee):
                return True
            if callee in calls:
                stack.append(callee)
    return False


def check_dispatcher_convention(mod: ModuleLint) -> None:
    """Every public entry in a kernels/*/ops.py that (transitively)
    reaches a `*_pallas` call must also reach `validate_block` (RL103)
    and a routing predicate of the `routes_to_oracle` / `is_ragged`
    family (RL104) — the convention PR 5 had to retrofit by hand."""
    if not OPS_FILE_RE.search(mod.rel):
        return
    fns = {n.name: n for n in mod.tree.body
           if isinstance(n, ast.FunctionDef)}
    calls = {name: _call_names(fn) for name, fn in fns.items()}
    for name, fn in fns.items():
        if name.startswith("_"):
            continue
        if not _reaches(name, calls,
                        lambda c: bool(PALLAS_CALLEE_RE.search(c))):
            continue
        if not _reaches(name, calls, lambda c: c == VALIDATOR_NAME):
            mod.flag(fn, "RL103",
                     f"dispatcher entry '{name}' reaches a pallas call "
                     f"without common.validate_block")
        if not _reaches(name, calls,
                        lambda c: bool(PREDICATE_RE.search(c))):
            mod.flag(fn, "RL104",
                     f"dispatcher entry '{name}' reaches a pallas call "
                     f"without a routes_to_oracle-family predicate")


# --- namespaced autotune keys (RL105) ------------------------------------

def _literal_key_lacks_namespace(key: ast.AST) -> bool:
    if isinstance(key, ast.Constant) and isinstance(key.value, str):
        return "/" not in key.value
    if isinstance(key, ast.JoinedStr):
        consts = "".join(v.value for v in key.values
                         if isinstance(v, ast.Constant)
                         and isinstance(v.value, str))
        return "/" not in consts
    return False


def check_autotune_keys(mod: ModuleLint) -> None:
    """Stores into the autotune caches (`_memory_cache[...]`,
    `disk[...]`) must use namespaced "<kernel>/..." keys: a literal or
    f-string key whose constant text carries no "/" is the bare-key
    regression class PR 4 migrated away from."""
    for node in ast.walk(mod.tree):
        if not isinstance(node, (ast.Assign, ast.AugAssign)):
            continue
        targets = node.targets if isinstance(node, ast.Assign) \
            else [node.target]
        for tgt in targets:
            if not isinstance(tgt, ast.Subscript):
                continue
            base = dotted_name(tgt.value)
            if base is None or not CACHE_DICT_RE.match(
                    base.split(".")[-1]):
                continue
            if _literal_key_lacks_namespace(tgt.slice):
                mod.flag(node, "RL105",
                         "autotune cache keys must be namespaced "
                         "'<kernel>/...' (use cache_key())")


# --- jax.config mutation (RL106) -----------------------------------------

def check_config_mutation(mod: ModuleLint) -> None:
    if Path(mod.rel).name in CONFIG_ALLOWLIST:
        return
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call):
            name = mod.canonical(node.func)
            if name == "jax.config.update":
                mod.flag(node, "RL106",
                         "jax.config.update outside the allowlist — "
                         "config belongs to the process owner, not a "
                         "library module")
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                name = mod.canonical(tgt)
                if name and name.startswith("jax.config."):
                    mod.flag(node, "RL106",
                             f"assignment to '{name}' outside the "
                             f"allowlist")


# --- tracer hazards (RL107) ----------------------------------------------

def _is_jit_decorator(mod: ModuleLint, dec: ast.AST) -> bool:
    name = mod.canonical(dec)
    if name in ("jax.jit", "jit"):
        return True
    if isinstance(dec, ast.Call):
        fname = mod.canonical(dec.func)
        if fname in ("jax.jit", "jit"):
            return True
        if fname in ("functools.partial", "partial") and dec.args:
            return mod.canonical(dec.args[0]) in ("jax.jit", "jit")
    return False


def _jit_roots(mod: ModuleLint,
               fns: Dict[str, ast.FunctionDef]) -> Set[str]:
    roots = {name for name, fn in fns.items()
             if any(_is_jit_decorator(mod, d) for d in fn.decorator_list)}
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Call) \
                and mod.canonical(node.func) in ("jax.jit", "jit"):
            for arg in node.args[:1]:
                if isinstance(arg, ast.Name) and arg.id in fns:
                    roots.add(arg.id)
    return roots


def _traced_locals(mod: ModuleLint, fn: ast.FunctionDef) -> Set[str]:
    """Names assigned from jnp/jax-producing calls inside `fn` — the
    values a Python cast or branch would force under trace."""
    traced: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            cname = mod.canonical(node.value.func)
            if cname and cname.startswith(TRACED_MODULE_PREFIXES):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        traced.add(tgt.id)
    return traced


def _mentions_traced(mod: ModuleLint, expr: ast.AST,
                     traced: Set[str]) -> bool:
    # `x is None` / `x is not None` identity checks are trace-safe
    # Python (they never force a tracer's value) — prune them before
    # looking for traced mentions
    if isinstance(expr, ast.Compare) \
            and all(isinstance(op, (ast.Is, ast.IsNot)) for op in expr.ops):
        return False
    if isinstance(expr, ast.BoolOp):
        return any(_mentions_traced(mod, v, traced) for v in expr.values)
    if isinstance(expr, ast.UnaryOp):
        return _mentions_traced(mod, expr.operand, traced)
    for node in ast.walk(expr):
        if isinstance(node, ast.Call):
            cname = mod.canonical(node.func)
            if cname and cname.startswith(TRACED_MODULE_PREFIXES):
                return True
        if isinstance(node, ast.Name) and node.id in traced:
            return True
    return False


def _jit_reachable(mod: ModuleLint, fns: Dict[str, ast.FunctionDef],
                   calls: Dict[str, Set[str]]) -> Set[str]:
    """Module-local functions reachable from a jit entry point
    (decorator or direct `jax.jit(f)`) via the intra-module call graph
    — the shared reachability core of RL107 and RL108."""
    reachable: Set[str] = set()
    stack = list(_jit_roots(mod, fns))
    while stack:
        name = stack.pop()
        if name in reachable:
            continue
        reachable.add(name)
        stack.extend(c for c in calls.get(name, ()) if c in fns)
    return reachable


def check_tracer_hazards(mod: ModuleLint) -> None:
    """Inside functions reachable from a jit entry point (decorator or
    direct `jax.jit(f)`), flag the targeted hazard patterns: `.item()`,
    `float()/int()/bool()` on a jnp-derived value, and Python `if`/
    `while` branching on one — each forces a traced value to a Python
    scalar and fails (or silently constant-folds) under jit. Shape
    ints, flags, and oracle routing predicates never match."""
    fns = {n.name: n for n in mod.tree.body
           if isinstance(n, ast.FunctionDef)}
    calls = {name: _call_names(fn) for name, fn in fns.items()}
    for name in _jit_reachable(mod, fns, calls):
        fn = fns[name]
        traced = _traced_locals(mod, fn)
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr == "item":
                    mod.flag(node, "RL107",
                             f".item() in jit-reachable '{name}'")
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in CAST_NAMES \
                        and len(node.args) == 1 \
                        and not isinstance(node.args[0], ast.Constant) \
                        and _mentions_traced(mod, node.args[0], traced):
                    mod.flag(node, "RL107",
                             f"{node.func.id}() on a traced value in "
                             f"jit-reachable '{name}'")
            elif isinstance(node, (ast.If, ast.While)) \
                    and _mentions_traced(mod, node.test, traced):
                mod.flag(node, "RL107",
                         f"Python branch on a traced value in "
                         f"jit-reachable '{name}' — use lax.cond/"
                         f"lax.while_loop")


# --- telemetry in traced code (RL108) ------------------------------------

OBS_MODULE = "repro.obs"


def check_obs_in_jit(mod: ModuleLint) -> None:
    """`repro.obs` counter/span calls must never sit in jit-reachable
    code: under trace they would fire once per COMPILATION (silently
    under-counting every cached re-execution), and a span would time
    tracing, not the computation. Reuses RL107's jit-root reachability.
    Record eagerly from a non-jitted wrapper guarded by
    `jax.core.trace_state_clean()` (the engine pattern), or route
    trace-time decisions through `kernels.common.record_route` — the
    one audited funnel, whose counters are documented as
    per-compilation."""
    fns = {n.name: n for n in mod.tree.body
           if isinstance(n, ast.FunctionDef)}
    calls = {name: _call_names(fn) for name, fn in fns.items()}
    for name in _jit_reachable(mod, fns, calls):
        for node in ast.walk(fns[name]):
            if not isinstance(node, ast.Call):
                continue
            cname = mod.canonical(node.func)
            if cname == OBS_MODULE \
                    or (cname and cname.startswith(OBS_MODULE + ".")):
                mod.flag(node, "RL108",
                         f"'{cname}' called in jit-reachable '{name}' — "
                         f"record eagerly (trace_state_clean-guarded "
                         f"wrapper) or via kernels.common.record_route")


# --- swallowed exceptions (RL109) ----------------------------------------

_BROAD_EXC = {"Exception", "BaseException", "builtins.Exception",
              "builtins.BaseException"}


def _is_broad_handler(mod: ModuleLint, handler: ast.ExceptHandler) -> bool:
    """Bare `except:`, or a clause (or tuple member) catching
    Exception/BaseException."""
    if handler.type is None:
        return True
    types = handler.type.elts if isinstance(handler.type, ast.Tuple) \
        else [handler.type]
    return any(mod.canonical(t) in _BROAD_EXC for t in types)


def _handler_records(mod: ModuleLint, handler: ast.ExceptHandler) -> bool:
    """Does the handler body re-raise, record to `repro.obs`, or capture
    the traceback? (The three accepted ways to not lose the error.)"""
    for node in ast.walk(ast.Module(body=handler.body, type_ignores=[])):
        if isinstance(node, ast.Raise):
            return True
        if isinstance(node, ast.Call):
            cname = mod.canonical(node.func)
            if cname and (cname == OBS_MODULE
                          or cname.startswith(OBS_MODULE + ".")
                          or cname.startswith("traceback.")):
                return True
    return False


def check_exception_swallowing(mod: ModuleLint) -> None:
    """Broad handlers (`except:` / `except Exception` / BaseException)
    must not swallow the error silently: the body has to re-raise,
    record a `repro.obs` counter, or capture the traceback. A silent
    `pass`/`return` fallback turns every future failure — a torn
    checkpoint, a dead backend probe — into undebuggable nothing; the
    resilience layer (DESIGN.md §15) depends on degraded paths staying
    observable. Narrowing to the concrete exception types also
    satisfies the rule."""
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.ExceptHandler):
            continue
        if _is_broad_handler(mod, node) and not _handler_records(mod, node):
            mod.flag(node, "RL109",
                     "broad exception handler swallows the error "
                     "silently — re-raise, narrow the exception types, "
                     "record a repro.obs counter, or capture the "
                     "traceback")


# --- driver --------------------------------------------------------------

ALL_CHECKS = (
    check_import_boundaries,
    check_dispatcher_convention,
    check_autotune_keys,
    check_config_mutation,
    check_tracer_hazards,
    check_obs_in_jit,
    check_exception_swallowing,
)


def iter_py_files(paths: Iterable[str]) -> Iterable[Path]:
    for p in paths:
        path = Path(p)
        if path.is_file() and path.suffix == ".py":
            yield path
        elif path.is_dir():
            yield from sorted(f for f in path.rglob("*.py")
                              if "__pycache__" not in f.parts)


def lint_file(path: Path, rel: str | None = None) -> List[Finding]:
    rel = rel if rel is not None else str(path)
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 0, "RL100",
                        f"syntax error: {e.msg}")]
    mod = ModuleLint(path, rel, tree)
    for check in ALL_CHECKS:
        check(mod)
    return mod.findings


def lint_paths(paths: Iterable[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_file(path))
    return sorted(findings)
