"""Engine 3: concurrency contract checker (RL401-RL405).

Pure stdlib — like Engine 1, importing and running this module never
imports jax, so the `--concurrency` CLI leg stays accelerator-free.

The serving stack (PR 8/9) keeps its predictions coherent through a
small set of synchronization conventions — single-assignment atomic
publication of immutable snapshots, worker-thread-only queue state,
lock-guarded registries — that until this engine existed only as
docstrings. Here they become *declared* contracts: a class that spawns
threads (or is shared across them) carries a `_SYNC_POLICY` class
attribute mapping each shared instance attribute to the discipline that
keeps it coherent, and the checker proves the class body honors the
declaration. DESIGN.md §17 documents the schema and every code.

`_SYNC_POLICY` is a dict literal of attribute name -> policy string
(a `"*"` key declares the default for attributes not named):

* ``"atomic-publish[:site,...]"`` — the attribute is published by
  whole-object single assignment of a locally built value (atomic under
  the GIL), only inside ``__init__`` and the enumerated method sites.
  Compound (``+=``) or subscript mutation anywhere, assignment outside
  the site set, or a read-modify-write (the attribute appearing in its
  own right-hand side) is RL402.
* ``"worker-only:entry[,extra...]"`` — the attribute is touched (read
  OR written) only inside the intra-class call-graph closure of the
  worker entry method (plus explicitly enumerated extra roots, plus
  ``__init__``, which happens-before the thread exists). The closure is
  the same intra-module BFS RL103 uses for dispatcher validation. Any
  access outside it is RL403.
* ``"lock:<name>"`` — every access outside ``__init__`` sits lexically
  inside ``with self.<name>``; a naked access is RL402. Additionally,
  RL404 flags blocking calls made while any declared lock is held:
  an engine solve (``refit`` / ``solve_*``), a bare ``.result()``, a
  timeout-less ``.get()``, or a timeout-less ``.join()`` — each can
  stall every other thread contending for the lock.
* ``"immutable-after-init"`` — written (or mutated) only in
  ``__init__``; reads need no synchronization afterwards. Any later
  write is RL402.

A class is *checked* when it declares `_SYNC_POLICY` or when it spawns
threads (`threading.Thread(...)` anywhere in its body); a thread
spawner with no declaration, or a checked class with an undeclared
shared attribute (and no `"*"` default), is RL401.

RL405 is module-scoped rather than class-scoped: a
`concurrent.futures.Future` constructed in library code must, in its
enclosing function, either be resolved (`set_result`/`set_exception`/
`cancel`), handed off (passed as a call argument — e.g. wrapped into a
request record that goes to the worker queue), or returned; and no
`raise`/`return` exit path may sit between its creation and the first
handoff. A dropped future strands its caller forever — the serving
front's `submit` contract exists precisely to prevent that.

Files in scope: everything under `src/repro/stream/` and
`src/repro/serving/`, plus any linted module that imports `threading`
or `concurrent.futures`.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from tools.repro_lint.findings import Finding
from tools.repro_lint.invariants import (
    ModuleLint, dotted_name, iter_py_files,
)

POLICY_ATTR = "_SYNC_POLICY"

# directories whose modules are always in scope, threading import or not
SCOPE_DIR_RE = re.compile(r"(^|/)repro/(stream|serving)/")

# call names whose completion depends on other threads' progress — held
# across a declared lock they convert contention into a stall (RL404)
SOLVE_CALL_RE = re.compile(r"^(refit|_refit\w*|solve_\w+)$")

_POLICIES = ("atomic-publish", "worker-only", "lock", "immutable-after-init")


# --- access model ----------------------------------------------------------

class Access:
    """One `self.<attr>` touch inside a method body."""

    __slots__ = ("attr", "kind", "node", "locks", "rmw")

    def __init__(self, attr: str, kind: str, node: ast.AST,
                 locks: frozenset, rmw: bool = False):
        self.attr = attr
        self.kind = kind          # "read" | "write" | "mutate"
        self.node = node
        self.locks = locks        # lexically held `with self.<lock>` names
        self.rmw = rmw            # write whose RHS reads the same attr


def _self_attr(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and \
            isinstance(node.value, ast.Name) and node.value.id == "self":
        return node.attr
    return None


def _attr_reads(expr: ast.AST) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(expr):
        attr = _self_attr(node)
        if attr is not None:
            out.add(attr)
    return out


class _MethodScan(ast.NodeVisitor):
    """Collects every self-attribute access, every `self.m()` call edge,
    and every call made under a held `with self.<lock>` block."""

    def __init__(self) -> None:
        self.accesses: List[Access] = []
        self.calls: Set[str] = set()                  # self.m() edges
        self.locked_calls: List[Tuple[ast.Call, frozenset]] = []
        self._locks: Tuple[str, ...] = ()

    # -- helpers ----------------------------------------------------------

    def _held(self) -> frozenset:
        return frozenset(self._locks)

    def _record_store(self, target: ast.AST, value: Optional[ast.AST],
                      root: ast.AST) -> None:
        attr = _self_attr(target)
        if attr is not None:
            rmw = value is not None and attr in _attr_reads(value)
            self.accesses.append(
                Access(attr, "write", root, self._held(), rmw=rmw))
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            for elt in target.elts:
                self._record_store(elt, value, root)
            return
        if isinstance(target, ast.Subscript):
            base = _self_attr(target.value)
            if base is not None:
                self.accesses.append(
                    Access(base, "mutate", root, self._held()))
            else:
                self.visit(target.value)
            self.visit(target.slice)
            return
        if isinstance(target, ast.Attribute):
            # store onto a non-self object: its base is still read
            self.visit(target.value)

    # -- statements -------------------------------------------------------

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            self._record_store(tgt, node.value, node)
        self.visit(node.value)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        attr = _self_attr(node.target)
        if attr is not None:
            self.accesses.append(
                Access(attr, "mutate", node, self._held(), rmw=True))
        elif isinstance(node.target, ast.Subscript):
            base = _self_attr(node.target.value)
            if base is not None:
                self.accesses.append(
                    Access(base, "mutate", node, self._held()))
            else:
                self.visit(node.target)
        else:
            self.visit(node.target)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_store(node.target, node.value, node)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for tgt in node.targets:
            attr = _self_attr(tgt)
            if attr is not None:
                self.accesses.append(
                    Access(attr, "write", node, self._held()))
            else:
                self.visit(tgt)

    def visit_With(self, node: ast.With) -> None:
        acquired = []
        for item in node.items:
            lock = _self_attr(item.context_expr)
            if lock is not None:
                # the lock attribute itself is read at acquisition
                self.accesses.append(
                    Access(lock, "read", item.context_expr, self._held()))
                acquired.append(lock)
            else:
                self.visit(item.context_expr)
            if item.optional_vars is not None:
                self.visit(item.optional_vars)
        self._locks = self._locks + tuple(acquired)
        for stmt in node.body:
            self.visit(stmt)
        if acquired:
            self._locks = self._locks[:-len(acquired)]

    # -- expressions --------------------------------------------------------

    def visit_Call(self, node: ast.Call) -> None:
        if self._locks:
            self.locked_calls.append((node, self._held()))
        attr = _self_attr(node.func)
        if attr is not None:
            self.calls.add(attr)
            self.accesses.append(
                Access(attr, "read", node.func, self._held()))
        else:
            self.visit(node.func)
        for arg in node.args:
            self.visit(arg)
        for kw in node.keywords:
            self.visit(kw.value)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        attr = _self_attr(node)
        if attr is not None:
            self.accesses.append(
                Access(attr, "read", node, self._held()))
            return
        self.visit(node.value)


def scan_method(fn: ast.FunctionDef) -> _MethodScan:
    scan = _MethodScan()
    for stmt in fn.body:
        scan.visit(stmt)
    return scan


# --- policy parsing --------------------------------------------------------

class Policy:
    __slots__ = ("kind", "sites")

    def __init__(self, kind: str, sites: Tuple[str, ...] = ()):
        self.kind = kind
        self.sites = sites


def parse_policy(text: str) -> Optional[Policy]:
    """"atomic-publish:publish_model" -> Policy; None when malformed."""
    kind, _, rest = text.partition(":")
    sites = tuple(s.strip() for s in rest.split(",") if s.strip()) \
        if rest else ()
    if kind == "atomic-publish":
        return Policy(kind, sites)
    if kind == "worker-only":
        return Policy(kind, sites) if sites else None
    if kind == "lock":
        return Policy(kind, sites) if len(sites) == 1 else None
    if kind == "immutable-after-init":
        return Policy(kind) if not rest else None
    return None


def extract_sync_policy(cls: ast.ClassDef) -> Tuple[Optional[dict], bool]:
    """(raw {attr: policy-string} or None, well_formed). The declaration
    must be a dict literal of constant strings — the checker reads it
    statically, so computed policies would be unenforceable."""
    for node in cls.body:
        target = None
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            target, value = node.targets[0], node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            target, value = node.target, node.value
        else:
            continue
        if not (isinstance(target, ast.Name) and target.id == POLICY_ATTR):
            continue
        if not isinstance(value, ast.Dict):
            return None, False
        out = {}
        for k, v in zip(value.keys, value.values):
            if not (isinstance(k, ast.Constant) and isinstance(k.value, str)
                    and isinstance(v, ast.Constant)
                    and isinstance(v.value, str)):
                return None, False
            out[k.value] = v.value
        return out, True
    return None, True


# --- class-level checks ----------------------------------------------------

def _class_methods(cls: ast.ClassDef) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _closure(roots: Iterable[str], calls: Dict[str, Set[str]]) -> Set[str]:
    """Transitive closure over the intra-class `self.m()` call graph —
    the same BFS RL103 runs over a module's dispatcher helpers."""
    seen: Set[str] = set()
    stack = list(roots)
    while stack:
        name = stack.pop()
        if name in seen:
            continue
        seen.add(name)
        stack.extend(calls.get(name, ()))
    return seen


def _spawns_thread(mod: ModuleLint, cls: ast.ClassDef) -> bool:
    for node in ast.walk(cls):
        if isinstance(node, ast.Call):
            if mod.canonical(node.func) == "threading.Thread":
                return True
    return False


def _is_blocking_call(call: ast.Call) -> Optional[str]:
    """The RL404 taxonomy: calls that park the calling thread on
    another thread's progress."""
    if isinstance(call.func, ast.Attribute):
        meth = call.func.attr
        if meth == "result" and not call.args and not call.keywords:
            return "Future.result() with no timeout"
        if meth == "get" and not call.args and \
                not any(kw.arg == "timeout" for kw in call.keywords):
            return "Queue.get() with no timeout"
        if meth == "join" and not call.args and \
                not any(kw.arg == "timeout" for kw in call.keywords):
            return "join() with no timeout"
    name = dotted_name(call.func)
    leaf = name.split(".")[-1] if name else ""
    if SOLVE_CALL_RE.match(leaf):
        return f"engine solve '{leaf}'"
    return None


def check_class(mod: ModuleLint, cls: ast.ClassDef) -> None:
    raw, well_formed = extract_sync_policy(cls)
    spawns = _spawns_thread(mod, cls)
    if not well_formed:
        mod.flag(cls, "RL401",
                 f"class '{cls.name}': {POLICY_ATTR} must be a dict "
                 f"literal of constant strings (attr -> policy)")
        return
    if raw is None:
        if spawns:
            mod.flag(cls, "RL401",
                     f"class '{cls.name}' spawns threads but declares no "
                     f"{POLICY_ATTR} — every shared attribute needs a "
                     f"sync policy (DESIGN.md §17)")
        return

    methods = _class_methods(cls)
    scans = {name: scan_method(fn) for name, fn in methods.items()}
    calls = {name: {c for c in scan.calls if c in methods}
             for name, scan in scans.items()}

    policies: Dict[str, Policy] = {}
    default: Optional[Policy] = None
    for attr, text in raw.items():
        pol = parse_policy(text)
        if pol is None:
            mod.flag(cls, "RL401",
                     f"class '{cls.name}': malformed policy '{text}' for "
                     f"'{attr}' (want atomic-publish[:sites] / "
                     f"worker-only:entry[,extra] / lock:<name> / "
                     f"immutable-after-init)")
            continue
        if attr == "*":
            default = pol
        else:
            policies[attr] = pol

    # instance attributes this class owns = everything it ever assigns
    assigned: Dict[str, ast.AST] = {}
    for name, scan in scans.items():
        for acc in scan.accesses:
            if acc.kind in ("write", "mutate") and acc.attr not in assigned:
                assigned[acc.attr] = acc.node
    for attr, first in sorted(assigned.items()):
        if attr not in policies:
            if default is None:
                mod.flag(first, "RL401",
                         f"class '{cls.name}': shared attribute "
                         f"'{attr}' has no declared sync policy and "
                         f"{POLICY_ATTR} has no '*' default")
            else:
                policies[attr] = default

    declared_locks = {p.sites[0] for p in policies.values()
                      if p.kind == "lock"}

    # worker-only closures, one per distinct root set
    closures: Dict[Tuple[str, ...], Set[str]] = {}
    for pol in policies.values():
        if pol.kind == "worker-only" and pol.sites not in closures:
            closures[pol.sites] = _closure(pol.sites, calls)

    for mname, scan in scans.items():
        in_init = mname == "__init__"
        for acc in scan.accesses:
            pol = policies.get(acc.attr)
            if pol is None:
                continue
            if pol.kind == "immutable-after-init":
                if acc.kind in ("write", "mutate") and not in_init:
                    mod.flag(acc.node, "RL402",
                             f"'{acc.attr}' is immutable-after-init but "
                             f"'{mname}' writes it")
            elif pol.kind == "atomic-publish":
                if acc.kind == "mutate" and not in_init:
                    mod.flag(acc.node, "RL402",
                             f"'{acc.attr}' is atomic-publish but "
                             f"'{mname}' mutates it in place (compound/"
                             f"subscript) — build a new value and "
                             f"single-assign it")
                elif acc.kind == "write" and not in_init:
                    if mname not in pol.sites:
                        mod.flag(acc.node, "RL402",
                                 f"'{acc.attr}' is atomic-publish with "
                                 f"closed site set "
                                 f"{{{', '.join(pol.sites) or '__init__'}}}"
                                 f" but '{mname}' assigns it")
                    elif acc.rmw:
                        mod.flag(acc.node, "RL402",
                                 f"'{acc.attr}' is atomic-publish but "
                                 f"'{mname}' read-modify-writes it — "
                                 f"the read and the publish are not one "
                                 f"atomic step")
            elif pol.kind == "worker-only":
                allowed = closures[pol.sites]
                if not in_init and mname not in allowed:
                    mod.flag(acc.node, "RL403",
                             f"'{acc.attr}' is worker-only (entry "
                             f"'{pol.sites[0]}') but '{mname}' touches "
                             f"it outside the worker's call graph")
            elif pol.kind == "lock":
                lock = pol.sites[0]
                if not in_init and lock not in acc.locks:
                    mod.flag(acc.node, "RL402",
                             f"'{acc.attr}' requires 'with self.{lock}' "
                             f"but '{mname}' touches it without the "
                             f"lock held")
        # RL404: blocking calls under any declared lock
        for call, locks in scan.locked_calls:
            if not (locks & declared_locks):
                continue
            why = _is_blocking_call(call)
            if why is not None:
                held = ", ".join(sorted(locks & declared_locks))
                mod.flag(call, "RL404",
                         f"blocking call ({why}) in '{mname}' while "
                         f"holding declared lock(s) {held}")


# --- RL405: dropped futures ------------------------------------------------

def _future_locals(mod: ModuleLint,
                   fn: ast.FunctionDef) -> Dict[str, ast.AST]:
    out: Dict[str, ast.AST] = {}
    for node in ast.walk(fn):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if mod.canonical(node.value.func) == "concurrent.futures.Future":
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        out[tgt.id] = node
    return out


def _mentions_name(node: ast.AST, name: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == name
               for n in ast.walk(node))


def check_dropped_futures(mod: ModuleLint) -> None:
    for fn in [n for n in ast.walk(mod.tree)
               if isinstance(n, ast.FunctionDef)]:
        futures = _future_locals(mod, fn)
        if not futures:
            continue
        for var, created in futures.items():
            handoffs: List[int] = []
            exits: List[Tuple[int, ast.AST]] = []
            for node in ast.walk(fn):
                line = getattr(node, "lineno", 0)
                if isinstance(node, ast.Call):
                    if isinstance(node.func, ast.Attribute) and \
                            isinstance(node.func.value, ast.Name) and \
                            node.func.value.id == var and node.func.attr in (
                                "set_result", "set_exception", "cancel"):
                        handoffs.append(line)
                    elif any(_mentions_name(a, var) for a in node.args) or \
                            any(_mentions_name(kw.value, var)
                                for kw in node.keywords):
                        handoffs.append(line)
                elif isinstance(node, ast.Return):
                    if node.value is not None and \
                            _mentions_name(node.value, var):
                        handoffs.append(line)
                    else:
                        exits.append((line, node))
                elif isinstance(node, ast.Raise):
                    exits.append((line, node))
            if not handoffs:
                mod.flag(created, "RL405",
                         f"'{var}' is a Future that '{fn.name}' neither "
                         f"resolves, returns, nor hands off — its waiter "
                         f"blocks forever")
                continue
            first = min(handoffs)
            born = created.lineno
            for line, node in exits:
                if born < line < first:
                    mod.flag(node, "RL405",
                             f"exit path leaves Future '{var}' (created "
                             f"line {born}) unresolved before its first "
                             f"handoff (line {first})")


# --- driver ----------------------------------------------------------------

def _in_scope(mod: ModuleLint) -> bool:
    if SCOPE_DIR_RE.search(mod.rel.replace("\\", "/")):
        return True
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                head = a.name.split(".")[0]
                if head in ("threading", "concurrent"):
                    return True
        elif isinstance(node, ast.ImportFrom) and node.module and \
                node.level == 0:
            if node.module.split(".")[0] in ("threading", "concurrent"):
                return True
    return False


def lint_concurrency_file(path, rel: str | None = None) -> List[Finding]:
    rel = rel if rel is not None else str(path)
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 0, "RL100",
                        f"syntax error: {e.msg}")]
    mod = ModuleLint(path, rel, tree)
    if not _in_scope(mod):
        return []
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            check_class(mod, node)
    check_dropped_futures(mod)
    return mod.findings


def check_concurrency(paths: Sequence[str]) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        findings.extend(lint_concurrency_file(path))
    return sorted(findings)
