"""Finding record + the registry of machine-checked invariant codes.

Every standing invariant in ROADMAP.md that the linter enforces has a
stable code here; DESIGN.md §13 documents each one with its rationale.
Codes are grouped by engine: RL1xx are AST invariant lints (pure
stdlib, no jax import), RL2xx are static tiling/VMEM contract checks
(import the dispatchers' own byte models and predicates, execute
nothing), RL3xx validate a committed autotune cache file (pure JSON,
no jax), RL4xx are concurrency contract checks over declared
`_SYNC_POLICY` maps (pure stdlib, no jax).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    path: str
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


CODES = {
    # Engine 1 — AST invariant lints (invariants.py)
    "RL101": "shard_map/mesh/collective plumbing imported outside "
             "src/repro/substrate/",
    "RL102": "pallas/pltpu imported outside kernels/*/kernel.py",
    "RL103": "kernel dispatcher entry reaches a pallas call without "
             "common.validate_block",
    "RL104": "kernel dispatcher entry reaches a pallas call without a "
             "routes_to_oracle-family predicate",
    "RL105": "autotune cache written under a bare (un-namespaced) key",
    "RL106": "jax.config mutated outside the approved allowlist",
    "RL107": "tracer hazard: Python cast/branch on a traced value in "
             "jit-reachable code",
    "RL108": "repro.obs counter/span call in jit-reachable code — "
             "telemetry must record eagerly or via the "
             "common.record_route funnel",
    "RL109": "broad exception handler (bare except / except Exception) "
             "swallows the error without re-raising, recording to "
             "repro.obs, or capturing the traceback",
    # Engine 2 — static tiling/VMEM contract checks (contracts.py)
    "RL201": "BlockSpec index_map arity disagrees with its pallas_call grid",
    "RL202": "BlockSpec tile parameter lacks a divisibility assert in its "
             "kernel wrapper module",
    "RL210": "dispatchable configuration busts the kernel's VMEM budget",
    "RL211": "dispatchable configuration resolves a non-divisor or "
             "misaligned tile",
    "RL212": "routing predicate disagrees with the resolver it gates",
    "RL213": "autotune candidate the dispatcher would refuse to serve",
    # --cache mode (cachecheck.py)
    "RL301": "autotune cache key is not namespaced '<kernel>/...'",
    "RL302": "autotune cache key has an unknown namespace or malformed "
             "dimension spec",
    "RL303": "autotune cache value has the wrong shape for its kernel",
    # Engine 3 — concurrency contract checks (concurrency.py)
    "RL401": "shared attribute of a thread-spawning/thread-shared class "
             "has no declared _SYNC_POLICY entry (or the policy is "
             "malformed)",
    "RL402": "access violates the attribute's declared sync policy "
             "(atomic-publish site set / read-modify-write, "
             "immutable-after-init write, lock discipline)",
    "RL403": "worker-only attribute reached from outside the worker's "
             "call graph",
    "RL404": "blocking call (engine solve, Future.result, timeout-less "
             "Queue.get/join) while a declared lock is held",
    "RL405": "Future created with an exit path that neither resolves it "
             "nor hands it off",
}
