"""repro_lint: the repo-native static-analysis pass.

Three engines plus a cache validator, all runnable via
`python -m tools.repro_lint` (see `__main__.py`):

* Engine 1 (`invariants.py`) — AST lints enforcing ROADMAP.md's
  standing invariants (RL1xx). Pure stdlib, never imports jax.
* Engine 2 (`contracts.py`) — static Pallas tiling/VMEM contract
  checks (RL2xx): AST BlockSpec geometry plus the dispatchers' own
  byte models and routing predicates evaluated over an adversarial
  shape×block grid. Imports the repro package (and so jax), executes
  no kernel, needs no TPU.
* Engine 3 (`concurrency.py`) — concurrency contract checks (RL4xx)
  over declared `_SYNC_POLICY` maps in thread-spawning/thread-shared
  classes. Pure stdlib, never imports jax.
* `--cache` (`cachecheck.py`) — committed autotune-cache key/value
  shape validation (RL3xx). Pure stdlib.

The pass is self-hosting: `tests/test_invariants.py` runs it over
`src/` and `benchmarks/` inside tier-1, so any new violation fails the
suite; `make lint` runs the same pass standalone.
"""
from tools.repro_lint.cachecheck import check_cache_file
from tools.repro_lint.findings import CODES, Finding
from tools.repro_lint.invariants import lint_file, lint_paths

__all__ = ["CODES", "Finding", "check_cache_file", "lint_file",
           "lint_paths", "run"]


def run(paths, *, contracts: bool = True, concurrency: bool = True):
    """Full lint: Engine 1 over `paths`, Engine 3 when `concurrency`
    (still pure stdlib), plus Engine 2 when `contracts` (imports jax
    transitively). Returns sorted findings."""
    findings = lint_paths(paths)
    if concurrency:
        from tools.repro_lint.concurrency import check_concurrency
        findings.extend(check_concurrency(paths))
    if contracts:
        from tools.repro_lint.contracts import check_contracts
        findings.extend(check_contracts(paths))
    return sorted(findings)
