"""Engine 2: static Pallas tiling/VMEM contract checks.

Two passes, neither of which needs a TPU or executes a kernel:

* **Geometry (RL201/RL202)** — pure AST over each
  `kernels/*/kernel.py`: every `pl.BlockSpec` fed to a `pl.pallas_call`
  must have an index_map whose arity matches that call's `grid`, and
  every symbolic tile parameter used in a BlockSpec shape must be
  covered by a `%`-divisibility assert somewhere in the wrapper module
  (the guard that turns a bad tile into a loud shape error instead of a
  silently wrong grid).

* **Dispatch contracts (RL210–RL213)** — imports the dispatchers' own
  routing predicates, resolvers, and byte models (`kernel_vmem_bytes`,
  `LOGISTIC_VMEM_BUDGET`, `rank_vmem_bytes`, `aligned_fit_block`) and
  evaluates them over an adversarial shape×block grid: every
  configuration the predicate lets through to the kernel must resolve
  to 8-aligned divisor tiles (RL211) inside the kernel's VMEM budget
  (RL210), the predicate and the resolver must agree with the
  dispatcher's own fused route-and-resolve path (RL212), and every
  tiling the autotuner would sweep must be one the dispatcher will
  actually serve (RL213 — a winner the dispatcher re-routes to the
  oracle is a shape that silently loses its kernel path forever).
  The grid pins the PR-5 regression shapes (n = 1016 = 8·127 sliver
  traps, p = 8168 budget-collapse, p = 16k+ accumulator blow-ups) so
  budget drift and alignment traps fail at lint time, before any test
  executes a kernel.

This module imports jax transitively (through the repro dispatchers) —
the `--cache` CLI mode never loads it.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, List, Set, Tuple

from tools.repro_lint.findings import Finding
from tools.repro_lint.invariants import (
    KERNEL_FILE_RE, dotted_name, iter_py_files,
)

# --- geometry pass (RL201 / RL202) ---------------------------------------


def _lambda_accepts(lam: ast.AST, arity: int) -> bool:
    if not isinstance(lam, ast.Lambda):
        return True                    # not statically checkable
    args = lam.args
    npos = len(args.args)
    if args.vararg is not None:
        return arity >= npos
    return arity == npos + len(args.kwonlyargs) * 0 \
        if not args.defaults else arity >= npos - len(args.defaults)


def _blockspec_nodes(call: ast.Call,
                     local_specs: Dict[str, List[ast.Call]]
                     ) -> List[ast.Call]:
    """Resolve the BlockSpec nodes fed to one pallas_call: direct
    `pl.BlockSpec(...)` calls, plus local-variable references resolved
    FLOW-SENSITIVELY to the latest assignment above the call (a wrapper
    with two pallas_call branches may rebind the same spec name per
    branch — e.g. the logistic full-lane vs feature-tiled layouts)."""
    out: List[ast.Call] = []

    def resolve(node: ast.AST) -> None:
        if isinstance(node, (ast.List, ast.Tuple)):
            for elt in node.elts:
                resolve(elt)
        elif isinstance(node, ast.Call):
            name = dotted_name(node.func)
            if name and name.split(".")[-1] == "BlockSpec":
                out.append(node)
        elif isinstance(node, ast.Name) and node.id in local_specs:
            prior = [spec for spec in local_specs[node.id]
                     if spec.lineno < call.lineno]
            if prior:
                out.append(max(prior, key=lambda spec: spec.lineno))

    for kw in call.keywords:
        if kw.arg in ("in_specs", "out_specs"):
            resolve(kw.value)
    return out


def _grid_arity(call: ast.Call) -> int | None:
    for kw in call.keywords:
        if kw.arg == "grid":
            if isinstance(kw.value, ast.Tuple):
                return len(kw.value.elts)
            return 1
    return None


def _module_divisibility_names(tree: ast.Module) -> Set[str]:
    """Names appearing inside `%`-expressions of asserts anywhere in
    the module — `assert n % bn == 0 and p % bp == 0` covers
    {n, bn, p, bp} even when the assert lives in a shared helper."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assert):
            for sub in ast.walk(node.test):
                if isinstance(sub, ast.BinOp) and isinstance(sub.op, ast.Mod):
                    for leaf in ast.walk(sub):
                        if isinstance(leaf, ast.Name):
                            names.add(leaf.id)
    return names


def check_kernel_geometry(path: Path, rel: str) -> List[Finding]:
    findings: List[Finding] = []
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError as e:
        return [Finding(rel, e.lineno or 0, "RL100",
                        f"syntax error: {e.msg}")]
    asserted = _module_divisibility_names(tree)
    for fn in [n for n in tree.body if isinstance(n, ast.FunctionDef)]:
        params = {a.arg for a in fn.args.args + fn.args.kwonlyargs}
        local_specs: Dict[str, List[ast.Call]] = {}
        pallas_calls: List[ast.Call] = []
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) \
                    and isinstance(node.value, ast.Call):
                vname = dotted_name(node.value.func)
                if vname and vname.split(".")[-1] == "BlockSpec":
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            local_specs.setdefault(tgt.id, []) \
                                .append(node.value)
            if isinstance(node, ast.Call):
                cname = dotted_name(node.func)
                if cname and cname.split(".")[-1] == "pallas_call":
                    pallas_calls.append(node)
        for call in pallas_calls:
            arity = _grid_arity(call)
            if arity is None:
                continue
            for spec in _blockspec_nodes(call, local_specs):
                # positional form: BlockSpec(shape, index_map)
                shape = spec.args[0] if spec.args else None
                imap = spec.args[1] if len(spec.args) > 1 else None
                if imap is not None and not _lambda_accepts(imap, arity):
                    findings.append(Finding(
                        rel, spec.lineno, "RL201",
                        f"BlockSpec index_map arity disagrees with "
                        f"grid arity {arity} in '{fn.name}'"))
                if isinstance(shape, ast.Tuple):
                    for elt in shape.elts:
                        if isinstance(elt, ast.Name) \
                                and elt.id in params \
                                and elt.id not in asserted:
                            findings.append(Finding(
                                rel, spec.lineno, "RL202",
                                f"tile parameter '{elt.id}' used in a "
                                f"BlockSpec of '{fn.name}' has no "
                                f"divisibility assert in this module"))
    return findings


def check_geometry(paths) -> List[Finding]:
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        rel = str(path)
        if KERNEL_FILE_RE.search(rel.replace("\\", "/")):
            findings.extend(check_kernel_geometry(path, rel))
    return findings


# --- dispatch-contract pass (RL210–RL213) --------------------------------

# adversarial shape grid: powers of two, the PR-5 sliver/alignment
# regressions (1016 = 8·127, 504 = 8·63, 8168 = 8·1021), ragged odds,
# small axes, and the budget-busting top end
GRID_N = (8, 30, 64, 120, 128, 200, 504, 1016, 1024, 4096)
GRID_P = (8, 64, 128, 200, 504, 1016, 2048, 2720, 4096, 8168, 8192,
          16384, 32768)
LOGISTIC_BLOCKS = (None, 8, 32, 128, 256, 1024,
                   (8, 8), (64, 8), (128, 128), (128, 1024), (128, 2048),
                   (256, 4096), (1024, 8))
RANK_BLOCKS = (8, 32, 64, 128, 256, (8, 8), (32, 128), (128, 32),
               (256, 256))
SOLVER_P = (8, 40, 80, 128, 504, 1016, 1024, 4096)
SOLVER_R = (1, 8, 64, 128)
SOLVER_BLOCKS = (8, 32, 128, 256, (48, 8, 48), (128, 1, 128),
                 (64, 8, 64))


def _aligned_divisor(size: int, tile: int) -> bool:
    return size % tile == 0 and (tile % 8 == 0 or tile == size)


def check_logistic_contract() -> List[Finding]:
    from repro.kernels.logistic_grad.ops import (
        LOGISTIC_VMEM_BUDGET, _route_and_resolve, kernel_vmem_bytes,
        resolve_logistic_blocks, routes_to_oracle,
    )
    rel = "src/repro/kernels/logistic_grad/ops.py"
    findings: List[Finding] = []
    for n in GRID_N:
        for p in GRID_P:
            for block in LOGISTIC_BLOCKS:
                reason, bn, bp = _route_and_resolve(n, p, block)
                if (reason is not None) != routes_to_oracle(n, p, block) \
                        or (bn, bp) != resolve_logistic_blocks(n, p, block):
                    findings.append(Finding(
                        rel, 0, "RL212",
                        f"routes_to_oracle/resolve_logistic_blocks "
                        f"disagree with _route_and_resolve at "
                        f"(n={n}, p={p}, block={block})"))
                if reason is not None:
                    continue
                if not (_aligned_divisor(n, bn)
                        and _aligned_divisor(p, bp)):
                    findings.append(Finding(
                        rel, 0, "RL211",
                        f"dispatchable (n={n}, p={p}, block={block}) "
                        f"resolves misaligned/non-divisor tiles "
                        f"(bn={bn}, bp={bp})"))
                if kernel_vmem_bytes(p, bn, bp) > LOGISTIC_VMEM_BUDGET:
                    findings.append(Finding(
                        rel, 0, "RL210",
                        f"dispatchable (n={n}, p={p}, block={block}) "
                        f"-> (bn={bn}, bp={bp}) busts "
                        f"LOGISTIC_VMEM_BUDGET: "
                        f"{kernel_vmem_bytes(p, bn, bp)} bytes"))
    return findings


def check_logistic_autotune_candidates() -> List[Finding]:
    from repro.kernels.autotune import logistic_candidates
    from repro.kernels.logistic_grad.ops import routes_to_oracle
    rel = "src/repro/kernels/autotune.py"
    findings: List[Finding] = []
    for n in GRID_N:
        for p in GRID_P:
            if routes_to_oracle(n, p):
                continue       # sweep never runs for oracle shapes
            for cand in logistic_candidates(n, p):
                if routes_to_oracle(n, p, cand):
                    findings.append(Finding(
                        rel, 0, "RL213",
                        f"logistic_candidates(n={n}, p={p}) offers "
                        f"{cand}, which the dispatcher routes to the "
                        f"oracle — a timed winner would silently lose "
                        f"the kernel path"))
    return findings


def check_rank_contract() -> List[Finding]:
    from repro.kernels.autotune import rank_candidates
    from repro.kernels.rank_update.ops import (
        RANK_VMEM_BUDGET, rank_routes_to_oracle, rank_vmem_bytes,
        resolve_rank_blocks,
    )
    rel = "src/repro/kernels/rank_update/ops.py"
    findings: List[Finding] = []
    for n in GRID_N:
        for p in GRID_P[:10]:
            for block in RANK_BLOCKS:
                if rank_routes_to_oracle(n, p, block):
                    continue
                bp, bn = resolve_rank_blocks(n, p, block)
                if not (_aligned_divisor(p, bp)
                        and _aligned_divisor(n, bn)):
                    findings.append(Finding(
                        rel, 0, "RL211",
                        f"dispatchable (n={n}, p={p}, block={block}) "
                        f"resolves misaligned/non-divisor tiles "
                        f"(bp={bp}, bn={bn})"))
                if rank_vmem_bytes(bp, bn) > RANK_VMEM_BUDGET:
                    findings.append(Finding(
                        rel, 0, "RL210",
                        f"dispatchable (n={n}, p={p}, block={block}) "
                        f"-> (bp={bp}, bn={bn}) busts RANK_VMEM_BUDGET: "
                        f"{rank_vmem_bytes(bp, bn)} bytes"))
            if not rank_routes_to_oracle(n, p):
                for cand in rank_candidates(n, p):
                    if rank_routes_to_oracle(n, p, cand):
                        findings.append(Finding(
                            "src/repro/kernels/autotune.py", 0, "RL213",
                            f"rank_candidates(n={n}, p={p}) offers "
                            f"{cand}, which the dispatcher routes to "
                            f"the oracle"))
    return findings


def check_solver_contract() -> List[Finding]:
    from repro.kernels.autotune import block_candidates
    from repro.kernels.ista_step.ops import is_ragged, resolve_blocks
    rel = "src/repro/kernels/ista_step/ops.py"
    findings: List[Finding] = []
    for p in SOLVER_P:
        for r in SOLVER_R:
            if is_ragged(p, r):
                continue
            for block in SOLVER_BLOCKS + tuple(block_candidates(p, r)):
                bp, br, bk = resolve_blocks(p, r, block)
                ok = (p % bp == 0 and r % br == 0 and p % bk == 0)
                if not ok:
                    findings.append(Finding(
                        rel, 0, "RL211",
                        f"dispatchable (p={p}, r={r}, block={block}) "
                        f"resolves non-divisor tiles "
                        f"(bp={bp}, br={br}, bk={bk})"))
    return findings


def check_master_contracts() -> List[Finding]:
    """group_threshold / flash_attention: resolver output must stay a
    divisor of its axis for every shape the predicate lets through."""
    from repro.kernels.flash_attention.ops import (
        flash_routes_to_oracle, resolve_flash_blocks,
    )
    from repro.kernels.group_threshold.ops import (
        group_routes_to_oracle, resolve_group_block,
    )
    findings: List[Finding] = []
    for p in GRID_P[:11] + (200000,):
        for block in (None, 8, 64, 256, 1024):
            if group_routes_to_oracle(p, block):
                continue
            bp = resolve_group_block(p, block)
            if not _aligned_divisor(p, bp):
                findings.append(Finding(
                    "src/repro/kernels/group_threshold/ops.py", 0,
                    "RL211",
                    f"dispatchable (p={p}, block={block}) resolves "
                    f"misaligned/non-divisor tile bp={bp}"))
    for S in (32, 64, 100, 128, 192, 256, 1016):
        for T in (64, 128, 256):
            for block in ((256, 256), (64, 64), (32, 128)):
                if flash_routes_to_oracle(S, T, block):
                    continue
                bq, bk = resolve_flash_blocks(S, T, block)
                if not (_aligned_divisor(S, bq)
                        and _aligned_divisor(T, bk)):
                    findings.append(Finding(
                        "src/repro/kernels/flash_attention/ops.py", 0,
                        "RL211",
                        f"dispatchable (S={S}, T={T}, block={block}) "
                        f"resolves misaligned/non-divisor tiles "
                        f"(bq={bq}, bk={bk})"))
    return findings


def check_dispatch_contracts() -> List[Finding]:
    findings: List[Finding] = []
    findings.extend(check_logistic_contract())
    findings.extend(check_logistic_autotune_candidates())
    findings.extend(check_rank_contract())
    findings.extend(check_solver_contract())
    findings.extend(check_master_contracts())
    return findings


def check_contracts(paths) -> List[Finding]:
    """Full Engine-2 run: AST geometry over the given paths plus the
    imported dispatch-contract grid."""
    findings = check_geometry(paths)
    findings.extend(check_dispatch_contracts())
    return sorted(findings)
