"""`--cache` mode: validate a committed autotune cache file.

Pure stdlib (json + re) — never imports jax, so this runs in CI jobs
and pre-commit hooks that have no accelerator stack at all. It catches
the legacy bare-key regression class from PR 4/5: every key in
`.cache/autotune.json` must be namespaced `"<kernel>/<backend>_<dims>_
<dtype>"` with the dimension spec and value arity that kernel's sweep
actually writes (`kernels/autotune.py::cache_key`).
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import List

from tools.repro_lint.findings import Finding

KEY_RE = re.compile(
    r"^(?P<kernel>[a-z0-9_]+)/(?P<backend>[a-z0-9]+)_"
    r"(?P<dims>[a-z]+\d+(?:_[a-z]+\d+)*)_(?P<dtype>[a-z0-9]+)$")

# kernel namespace -> (ordered dim letters, value arity)
KERNEL_SHAPES = {
    "fista_step": (("m", "p", "r"), 3),
    "logistic_grad": (("m", "n", "p"), 2),
    "rank_update": (("m", "n", "p"), 2),
}


def _dims_of(spec: str) -> tuple:
    return tuple(re.match(r"[a-z]+", part).group(0)
                 for part in spec.split("_"))


def _value_ok(value, arity: int) -> bool:
    if isinstance(value, list):
        return (len(value) == arity
                and all(isinstance(b, int) and not isinstance(b, bool)
                        and b >= 1 for b in value))
    # pre-namespace fista entries were bare ints (square blocks); they
    # are migrated to triples on load, but a committed int is still a
    # servable legacy form for fista_step only
    return (arity == 3 and isinstance(value, int)
            and not isinstance(value, bool) and value >= 1)


def check_cache_file(path: str | Path) -> List[Finding]:
    path = Path(path)
    rel = str(path)
    if not path.exists():
        return []                      # nothing committed, nothing to check
    try:
        entries = json.loads(path.read_text())
    except ValueError as e:
        return [Finding(rel, 0, "RL302", f"unparseable JSON: {e}")]
    if not isinstance(entries, dict):
        return [Finding(rel, 0, "RL302",
                        "cache root must be a JSON object")]
    findings: List[Finding] = []
    for key, value in entries.items():
        if "/" not in key:
            findings.append(Finding(
                rel, 0, "RL301",
                f"bare (un-namespaced) key {key!r} — the pre-PR-4 "
                f"regression class; keys must be '<kernel>/...'"))
            continue
        m = KEY_RE.match(key)
        if not m or m.group("kernel") not in KERNEL_SHAPES:
            findings.append(Finding(
                rel, 0, "RL302",
                f"key {key!r} has an unknown namespace or malformed "
                f"'<kernel>/<backend>_<dims>_<dtype>' spec"))
            continue
        dims, arity = KERNEL_SHAPES[m.group("kernel")]
        if _dims_of(m.group("dims")) != dims:
            findings.append(Finding(
                rel, 0, "RL302",
                f"key {key!r} carries dims "
                f"{_dims_of(m.group('dims'))}, expected {dims} for "
                f"'{m.group('kernel')}'"))
        if not _value_ok(value, arity):
            findings.append(Finding(
                rel, 0, "RL303",
                f"value for {key!r} must be a list of {arity} positive "
                f"ints, got {value!r}"))
    return findings
