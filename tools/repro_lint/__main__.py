"""CLI for the repro static-analysis pass.

    python -m tools.repro_lint src benchmarks      # all engines
    python -m tools.repro_lint --no-contracts src  # skip Engine 2 (no jax)
    python -m tools.repro_lint --concurrency src   # Engine 3 only (no jax)
    python -m tools.repro_lint --cache             # cache file only (no jax)
    python -m tools.repro_lint --cache .cache/autotune.json

Exit status: 0 when clean, 1 when any finding fires, 2 on usage error.
"""
from __future__ import annotations

import argparse
import sys



def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.repro_lint",
        description="repo-native invariant linter + static Pallas "
                    "tiling/VMEM contract checker")
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (e.g. src benchmarks)")
    ap.add_argument("--cache", nargs="?", const=".cache/autotune.json",
                    default=None, metavar="FILE",
                    help="validate an autotune cache file (default "
                         ".cache/autotune.json) and nothing else; never "
                         "imports jax")
    ap.add_argument("--no-contracts", action="store_true",
                    help="skip Engine 2 (the jax-importing dispatch-"
                         "contract grid); AST lints only")
    ap.add_argument("--concurrency", action="store_true",
                    help="run only Engine 3, the concurrency contract "
                         "checker (RL4xx); pure stdlib, never imports jax")
    args = ap.parse_args(argv)

    if args.cache is not None:
        from tools.repro_lint.cachecheck import check_cache_file
        findings = check_cache_file(args.cache)
        label = f"cache check over {args.cache}"
    elif args.concurrency:
        if not args.paths:
            ap.error("give paths to lint with --concurrency")
        from tools.repro_lint.concurrency import check_concurrency
        findings = check_concurrency(args.paths)
        label = f"concurrency lint over {' '.join(args.paths)}"
    else:
        if not args.paths:
            ap.error("give paths to lint, or --cache")
        from tools.repro_lint import run
        findings = run(args.paths, contracts=not args.no_contracts)
        label = f"lint over {' '.join(args.paths)}"

    for f in findings:
        print(f.render())
    if findings:
        by_code: dict = {}
        for f in findings:
            by_code[f.code] = by_code.get(f.code, 0) + 1
        summary = ", ".join(f"{c} x{n}" for c, n in sorted(by_code.items()))
        print(f"repro_lint: {len(findings)} finding(s) [{summary}] "
              f"({label})", file=sys.stderr)
        return 1
    print(f"repro_lint: clean ({label})", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
