"""Optional bridge to `jax.profiler.trace`.

Kept out of `repro.obs.__init__` so the telemetry core never imports
jax (zero-dependency contract, DESIGN.md §14). Import this module
explicitly when you want XLA-level traces alongside the obs timeline:

    from repro.obs import jaxprof
    with jaxprof.profiler_trace("/tmp/jax-trace"):
        run_workload()
"""
from __future__ import annotations

import contextlib


@contextlib.contextmanager
def profiler_trace(log_dir: str, **kwargs):
    """Wrap a block in `jax.profiler.trace(log_dir)`; degrades to a
    no-op (with a registry counter marking the skip) when jax is not
    importable, so callers never need their own try/except."""
    from repro.obs import registry as _registry
    try:
        import jax
    except Exception:
        _registry.inc("obs.jaxprof.unavailable")
        yield
        return
    _registry.inc("obs.jaxprof.trace")
    with jax.profiler.trace(log_dir, **kwargs):
        yield
