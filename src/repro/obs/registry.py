"""Process-local telemetry core: counters, gauges, histograms, spans.

Zero-dependency by contract — this module (and everything else the
`repro.obs` package imports at module scope) is pure stdlib and NEVER
imports jax, so instrumented library code adds no import weight and the
snapshot tooling runs in jax-free contexts (pre-commit hooks, log
scrapers). The optional `jax.profiler` bridge lives in
`repro.obs.jaxprof` behind a lazy import for exactly this reason.

Semantics (DESIGN.md §14):

* **Counters** are monotonically increasing sums, **gauges** are
  last-write-wins values, **histograms** keep count/sum/min/max plus a
  bounded ring of the most recent `HIST_SAMPLE_CAP` raw observations
  (enough for rates, latency headlines, AND tail quantiles — the
  serving front's p50/p99 come from `hist_quantiles`, computed over
  the retained window, without bucket configuration), and **spans**
  time a `with` block on the monotonic clock, recording both a
  `<name>.ms` histogram observation and a Chrome trace event.
* Every metric takes free-form keyword **labels**; a (name, labels)
  pair is one series. Labels must be low-cardinality Python scalars
  (kernel names, route reasons, axis names — never array values).
* **`REPRO_OBS=0`** (or `false`/`off`) in the environment hard-disables
  the process-global registry at import time: every recording call
  becomes a single attribute-check no-op and spans return a shared
  null context manager, so disabled-mode overhead is a function call —
  `benchmarks/check_regression.py` gates it at <2% of every tracked
  kernel pair.
* All mutation happens under one lock — safe for the threaded serving
  paths — and the trace-event buffer is capped (oldest runs drop
  nothing; new events past the cap are counted as dropped instead of
  growing without bound).

Recording under jit: never call these from jit-reachable code (lint
code RL108). Dispatch-time decisions that genuinely happen at trace
time (kernel routing, autotune cache events, collective byte models)
funnel through audited helpers — `kernels.common.record_route`,
`substrate.collectives` — that record only Python-concrete values;
everything else records eagerly, guarded by
`jax.core.trace_state_clean` at the call site.
"""
from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

# cap on buffered Chrome trace events; past it, events are dropped and
# counted (a long-running service must not grow a timeline unbounded)
MAX_TRACE_EVENTS = 65536

# per-series cap on retained raw observations for quantile estimation:
# a sliding window of the newest samples (a serving p99 should reflect
# recent traffic, not the cold-start tail from an hour ago), bounded so
# a long-running service's memory stays fixed per series
HIST_SAMPLE_CAP = 4096

MetricKey = Tuple[str, Tuple[Tuple[str, Any], ...]]


def _env_enabled() -> bool:
    return os.environ.get("REPRO_OBS", "1").strip().lower() not in (
        "0", "false", "off")


def _key(name: str, labels: dict) -> MetricKey:
    return (name, tuple(sorted(labels.items())))


def _quantile(sorted_vals: List[float], q: float) -> float:
    """Linear-interpolated quantile of an ascending-sorted list."""
    if not sorted_vals:
        raise ValueError("quantile of empty sample set")
    if len(sorted_vals) == 1:
        return sorted_vals[0]
    pos = q * (len(sorted_vals) - 1)
    lo = int(pos)
    hi = min(lo + 1, len(sorted_vals) - 1)
    frac = pos - lo
    return sorted_vals[lo] * (1.0 - frac) + sorted_vals[hi] * frac


class _Hist:
    """count/sum/min/max summary plus a bounded ring of recent raw
    samples (newest `HIST_SAMPLE_CAP`) for windowed quantiles."""

    __slots__ = ("count", "total", "min", "max", "samples")

    def __init__(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.samples: deque = deque(maxlen=HIST_SAMPLE_CAP)

    def add(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        self.samples.append(value)


class _NullSpan:
    """Shared no-op context manager returned by disabled spans."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_reg", "_name", "_labels", "_t0")

    def __init__(self, reg: "Registry", name: str, labels: dict) -> None:
        self._reg = reg
        self._name = name
        self._labels = labels

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        dur_ns = time.perf_counter_ns() - self._t0
        self._reg._finish_span(self._name, self._labels, self._t0, dur_ns)
        return False


class Registry:
    """One process-local metric store. Library code uses the module
    globals below (`inc`/`set_gauge`/`observe`/`span`); constructing a
    private `Registry` directly is for tests and the disabled-mode
    overhead bench.

    Thread-sharing contract (`_SYNC_POLICY`, checked by repro_lint
    RL4xx): every mutable store is touched only under `_lock`;
    `_enabled` is set once at construction and read lock-free
    thereafter. RL404 additionally proves no blocking call ever runs
    while `_lock` is held, so a recording thread can never stall the
    serving worker on telemetry.
    """

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_counters": "lock:_lock",
        "_gauges": "lock:_lock",
        "_hists": "lock:_lock",
        "_events": "lock:_lock",
        "_dropped_events": "lock:_lock",
    }

    def __init__(self, enabled: bool = True) -> None:
        self._enabled = bool(enabled)
        self._lock = threading.Lock()
        self._counters: Dict[MetricKey, float] = {}
        self._gauges: Dict[MetricKey, float] = {}
        self._hists: Dict[MetricKey, _Hist] = {}
        self._events: List[dict] = []
        self._dropped_events = 0

    @property
    def enabled(self) -> bool:
        return self._enabled

    # -- write side -------------------------------------------------------

    def inc(self, name: str, value: float = 1, **labels) -> None:
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            self._counters[k] = self._counters.get(k, 0) + value

    def set_gauge(self, name: str, value: float, **labels) -> None:
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            self._gauges[k] = value

    def observe(self, name: str, value: float, **labels) -> None:
        if not self._enabled:
            return
        k = _key(name, labels)
        with self._lock:
            h = self._hists.get(k)
            if h is None:
                h = self._hists[k] = _Hist()
            h.add(value)

    def span(self, name: str, **labels):
        """Context manager timing its block on the monotonic clock. On
        exit records a `<name>.ms` histogram observation and buffers a
        Chrome trace event ("X" phase, microsecond timestamps) carrying
        `labels` as the event args."""
        if not self._enabled:
            return _NULL_SPAN
        return _Span(self, name, labels)

    def event(self, name: str, ts_us: float, dur_us: float,
              **labels) -> None:
        """Buffer an explicit Chrome trace event (e.g. reconstructed
        from an external timing) without the histogram side effect."""
        if not self._enabled:
            return
        self._push_event(name, labels, ts_us, dur_us)

    def _finish_span(self, name: str, labels: dict, t0_ns: int,
                     dur_ns: int) -> None:
        self.observe(f"{name}.ms", dur_ns / 1e6, **labels)
        self._push_event(name, labels, t0_ns / 1e3, dur_ns / 1e3)

    def _push_event(self, name: str, labels: dict, ts_us: float,
                    dur_us: float) -> None:
        ev = {"name": name, "ph": "X", "cat": "repro",
              "ts": ts_us, "dur": dur_us,
              "pid": os.getpid(), "tid": threading.get_ident(),
              "args": dict(labels)}
        with self._lock:
            if len(self._events) >= MAX_TRACE_EVENTS:
                self._dropped_events += 1
            else:
                self._events.append(ev)

    # -- read side --------------------------------------------------------

    def counter_total(self, name: str, **match) -> float:
        """Sum of every counter series named `name` whose labels are a
        superset of `match` (no kwargs = all series of that name)."""
        want = set(match.items())
        with self._lock:
            return sum(v for (n, lab), v in self._counters.items()
                       if n == name and want.issubset(lab))

    def hist_stats(self, name: str, **match) -> Optional[dict]:
        """Merged count/sum/min/max/mean over every histogram series
        named `name` whose labels contain `match`; None when no series
        matches."""
        want = set(match.items())
        merged = _Hist()
        with self._lock:
            for (n, lab), h in self._hists.items():
                if n == name and want.issubset(lab):
                    merged.count += h.count
                    merged.total += h.total
                    merged.min = min(merged.min, h.min)
                    merged.max = max(merged.max, h.max)
        if merged.count == 0:
            return None
        return {"count": merged.count, "sum": merged.total,
                "min": merged.min, "max": merged.max,
                "mean": merged.total / merged.count}

    def hist_quantiles(self, name: str, qs=(0.5, 0.99),
                       **match) -> Optional[dict]:
        """Windowed quantiles over the retained samples of every
        histogram series named `name` whose labels contain `match`.
        Returns {q: value} (linear interpolation between order
        statistics) or None when no samples are retained. The window is
        the newest `HIST_SAMPLE_CAP` observations per series — a
        serving tail estimate, not an all-time one."""
        want = set(match.items())
        with self._lock:
            pooled: List[float] = []
            for (n, lab), h in self._hists.items():
                if n == name and want.issubset(lab):
                    pooled.extend(h.samples)
        if not pooled:
            return None
        pooled.sort()
        return {q: _quantile(pooled, q) for q in qs}

    def trace_events(self) -> List[dict]:
        with self._lock:
            return [dict(ev) for ev in self._events]

    def snapshot(self) -> dict:
        """JSON-ready state dump (no trace events — those export via
        `repro.obs.export.chrome_trace`)."""
        with self._lock:
            counters = [{"name": n, "labels": dict(lab), "value": v}
                        for (n, lab), v in sorted(self._counters.items())]
            gauges = [{"name": n, "labels": dict(lab), "value": v}
                      for (n, lab), v in sorted(self._gauges.items())]
            hists = []
            for (n, lab), h in sorted(self._hists.items()):
                if not h.count:
                    continue
                entry = {"name": n, "labels": dict(lab), "count": h.count,
                         "sum": h.total, "min": h.min, "max": h.max,
                         "mean": h.total / h.count}
                if h.samples:
                    srt = sorted(h.samples)
                    entry["p50"] = _quantile(srt, 0.5)
                    entry["p99"] = _quantile(srt, 0.99)
                hists.append(entry)
            return {"enabled": self._enabled, "counters": counters,
                    "gauges": gauges, "histograms": hists,
                    "dropped_trace_events": self._dropped_events}

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._events.clear()
            self._dropped_events = 0


# -- the process-global registry ------------------------------------------

_REGISTRY = Registry(enabled=_env_enabled())


def get_registry() -> Registry:
    return _REGISTRY


def enabled() -> bool:
    """True unless REPRO_OBS disabled telemetry at import time. Hot
    call sites with per-record setup cost (string formatting, byte
    models) should check this first and skip the work entirely."""
    return _REGISTRY.enabled


def inc(name: str, value: float = 1, **labels) -> None:
    _REGISTRY.inc(name, value, **labels)


def set_gauge(name: str, value: float, **labels) -> None:
    _REGISTRY.set_gauge(name, value, **labels)


def observe(name: str, value: float, **labels) -> None:
    _REGISTRY.observe(name, value, **labels)


def span(name: str, **labels):
    return _REGISTRY.span(name, **labels)


def counter_total(name: str, **match) -> float:
    return _REGISTRY.counter_total(name, **match)


def hist_stats(name: str, **match) -> Optional[dict]:
    return _REGISTRY.hist_stats(name, **match)


def hist_quantiles(name: str, qs=(0.5, 0.99), **match) -> Optional[dict]:
    return _REGISTRY.hist_quantiles(name, qs, **match)


def reset() -> None:
    _REGISTRY.reset()
