"""Summarize a saved telemetry snapshot:

    python -m repro.obs SNAPSHOT.json [--prometheus] [--top N]
"""
from __future__ import annotations

import argparse

from . import export


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a repro.obs snapshot JSON file.")
    ap.add_argument("snapshot", help="path written by export.write_snapshot")
    ap.add_argument("--prometheus", action="store_true",
                    help="emit Prometheus text exposition instead of "
                         "the human summary")
    ap.add_argument("--top", type=int, default=20,
                    help="max series per section in the summary")
    args = ap.parse_args(argv)

    snap = export.load_snapshot(args.snapshot)
    if args.prometheus:
        print(export.to_prometheus(snap), end="")
    else:
        print(export.summarize(snap, top=args.top))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
