"""repro.obs — process-local runtime telemetry.

Usage from library code (hot paths check `enabled()` first):

    from repro import obs
    obs.inc("dispatch.route", kernel="logistic_grad", outcome="kernel")
    with obs.span("stream.refit"):
        ...

Disable with `REPRO_OBS=0` in the environment (checked once at
import). Export helpers live in `repro.obs.export`; summarize a saved
snapshot with `python -m repro.obs SNAPSHOT.json`. Never record from
jit-reachable code — lint code RL108 enforces this (DESIGN.md §14).
"""
from .registry import (  # noqa: F401
    HIST_SAMPLE_CAP,
    MAX_TRACE_EVENTS,
    Registry,
    counter_total,
    enabled,
    get_registry,
    hist_quantiles,
    hist_stats,
    inc,
    observe,
    reset,
    set_gauge,
    span,
)
from .export import (  # noqa: F401
    chrome_trace,
    load_snapshot,
    snapshot,
    summarize,
    to_prometheus,
    write_chrome_trace,
    write_snapshot,
)
