"""Exporters for the telemetry registry: JSON snapshot, Prometheus
text exposition, and Chrome trace-event JSON (loadable in Perfetto or
chrome://tracing).

Pure stdlib — same zero-dependency contract as `registry.py`.
"""
from __future__ import annotations

import json
import os
import re
from typing import Optional

from . import registry as _registry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_]")


def snapshot(reg: Optional[_registry.Registry] = None,
             meta: Optional[dict] = None) -> dict:
    """Registry state as a JSON-ready dict; `meta` (run metadata such
    as backend/git SHA) is attached under a `"meta"` key when given."""
    reg = reg or _registry.get_registry()
    snap = reg.snapshot()
    if meta is not None:
        snap["meta"] = dict(meta)
    return snap


def write_snapshot(path: str, reg: Optional[_registry.Registry] = None,
                   meta: Optional[dict] = None) -> dict:
    snap = snapshot(reg, meta=meta)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    return snap


def load_snapshot(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _prom_name(name: str) -> str:
    return "repro_" + _NAME_RE.sub("_", name)


def _prom_labels(labels: dict) -> str:
    if not labels:
        return ""
    body = ",".join(f'{_NAME_RE.sub("_", str(k))}="{v}"'
                    for k, v in sorted(labels.items()))
    return "{" + body + "}"


def to_prometheus(snap: dict) -> str:
    """Prometheus text exposition (format 0.0.4) of a snapshot dict.
    Counters get a `_total` suffix; histograms expand to
    `_count`/`_sum`/`_min`/`_max` series."""
    lines = []
    for c in snap.get("counters", []):
        lines.append("%s_total%s %s" % (
            _prom_name(c["name"]), _prom_labels(c["labels"]), c["value"]))
    for g in snap.get("gauges", []):
        lines.append("%s%s %s" % (
            _prom_name(g["name"]), _prom_labels(g["labels"]), g["value"]))
    for h in snap.get("histograms", []):
        base = _prom_name(h["name"])
        lab = _prom_labels(h["labels"])
        for suffix in ("count", "sum", "min", "max"):
            lines.append("%s_%s%s %s" % (base, suffix, lab, h[suffix]))
        for suffix in ("p50", "p99"):   # windowed quantiles, when
            if suffix in h:             # samples were retained
                lines.append("%s_%s%s %s" % (base, suffix, lab, h[suffix]))
    return "\n".join(lines) + ("\n" if lines else "")


def chrome_trace(reg: Optional[_registry.Registry] = None) -> dict:
    """Buffered span events as a Chrome trace-event JSON object
    (`{"traceEvents": [...]}`) — drop the file on ui.perfetto.dev or
    chrome://tracing to see the timeline."""
    reg = reg or _registry.get_registry()
    return {"traceEvents": reg.trace_events(), "displayTimeUnit": "ms"}


def write_chrome_trace(path: str,
                       reg: Optional[_registry.Registry] = None) -> dict:
    trace = chrome_trace(reg)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def summarize(snap: dict, top: int = 20) -> str:
    """Human-oriented text summary of a snapshot (the `python -m
    repro.obs` output): counters sorted by value, gauges, histogram
    headlines (count / mean / max)."""
    lines = []
    meta = snap.get("meta")
    if meta:
        lines.append("meta:")
        for k, v in sorted(meta.items()):
            if k == "telemetry":
                continue
            lines.append(f"  {k}: {v}")
    counters = sorted(snap.get("counters", []),
                      key=lambda c: -c["value"])[:top]
    if counters:
        lines.append("counters:")
        for c in counters:
            lab = _prom_labels(c["labels"])
            lines.append(f"  {c['name']}{lab} = {c['value']:g}")
    gauges = snap.get("gauges", [])[:top]
    if gauges:
        lines.append("gauges:")
        for g in gauges:
            lab = _prom_labels(g["labels"])
            lines.append(f"  {g['name']}{lab} = {g['value']:g}")
    hists = sorted(snap.get("histograms", []),
                   key=lambda h: -h["count"])[:top]
    if hists:
        lines.append("histograms:")
        for h in hists:
            lab = _prom_labels(h["labels"])
            tail = f" p99={h['p99']:.4g}" if "p99" in h else ""
            lines.append(
                f"  {h['name']}{lab}: n={h['count']} "
                f"mean={h['mean']:.4g} max={h['max']:.4g}{tail}")
    dropped = snap.get("dropped_trace_events", 0)
    if dropped:
        lines.append(f"dropped trace events: {dropped}")
    if not lines:
        lines.append("(empty snapshot)")
    return "\n".join(lines)
