"""Deterministic fault injection for the streaming DSML service.

Every fault the resilience layer claims to survive (DESIGN.md §15's
taxonomy) is scriptable here, seeded and replayable:

* **poisoned batches** — `apply_batch_fault` corrupts a clean chunk
  with NaN rows, Inf entries, or a magnitude outburst, at positions
  drawn from a caller-seeded generator;
* **fault schedules** — `build_schedule` lays those corruptions out
  over an ingest timeline (`FaultSchedule.fault_for(step)`), so a chaos
  run is a pure function of its seed;
* **refit divergence** — `DivergenceInjector` installs itself into the
  service's `_refit_impl` seam and NaN-poisons the *candidate* state of
  the next N refit attempts, exercising the health-check/rollback path
  without needing numerically divergent data;
* **torn writes** — `truncate_file` chops the tail off a checkpoint to
  simulate a crash mid-write on a filesystem without atomic rename
  (or a corrupted disk block), driving the manifest fallback path.

The SIGKILL-mid-ingest fault class needs a live process, not a
function: `repro.substrate.popen_probe` + `Popen.kill()` covers it
(see `tests/test_chaos.py`).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

BATCH_FAULT_KINDS = ("nan", "inf", "outlier")


class FaultEvent(NamedTuple):
    step: int      # ingest step (0-based) the fault fires on
    kind: str      # one of BATCH_FAULT_KINDS, or "diverge" / "truncate"


class FaultSchedule(NamedTuple):
    seed: int
    n_steps: int
    events: Tuple[FaultEvent, ...]

    def fault_for(self, step: int) -> Optional[str]:
        for ev in self.events:
            if ev.step == step:
                return ev.kind
        return None

    def by_kind(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for ev in self.events:
            out[ev.kind] = out.get(ev.kind, 0) + 1
        return out


def build_schedule(n_steps: int, seed: int, *,
                   kinds: Sequence[str] = BATCH_FAULT_KINDS,
                   per_kind: int = 2, start: int = 0) -> FaultSchedule:
    """A seeded schedule: `per_kind` events of each kind, at distinct
    steps drawn without replacement from `[start, n_steps)`. Same
    arguments -> identical schedule, every run. `start` reserves the
    first steps as guaranteed-clean (e.g. so a relative-magnitude
    guard has accepted traffic to learn its reference scale from)."""
    need = per_kind * len(kinds)
    if need > n_steps - start:
        raise ValueError(f"{need} events do not fit in steps "
                         f"[{start}, {n_steps})")
    rng = np.random.default_rng(seed)
    steps = rng.choice(np.arange(start, n_steps), size=need, replace=False)
    events = tuple(
        FaultEvent(int(step), kind)
        for step, kind in zip(sorted(int(s) for s in steps),
                              list(kinds) * per_kind))
    return FaultSchedule(seed=seed, n_steps=n_steps, events=events)


# -- batch corruption ------------------------------------------------------

def make_clean_batch(rng: np.random.Generator, m: int, n: int, p: int,
                     dtype=jnp.float32) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """A healthy standardized chunk: X ~ N(0,1), y a noisy linear read."""
    X = rng.standard_normal((m, n, p))
    w = rng.standard_normal((m, p)) / np.sqrt(p)
    y = np.einsum("tnp,tp->tn", X, w) + 0.1 * rng.standard_normal((m, n))
    return jnp.asarray(X, dtype), jnp.asarray(y, dtype)


def apply_batch_fault(X, y, kind: str, rng: np.random.Generator,
                      *, outlier_scale: float = 1e6):
    """Corrupt one chunk with fault `kind`; returns new (X, y) arrays.

    "nan"      one full row of one task becomes NaN;
    "inf"      a handful of scattered entries become +/-Inf;
    "outlier"  the whole chunk is scaled by `outlier_scale` (finite,
               so only the relative-magnitude gate can catch it).
    """
    Xc = np.asarray(X, dtype=np.float64).copy()
    yc = np.asarray(y, dtype=np.float64).copy()
    m, n, p = Xc.shape
    if kind == "nan":
        t, i = int(rng.integers(m)), int(rng.integers(n))
        Xc[t, i, :] = np.nan
        yc[t, i] = np.nan
    elif kind == "inf":
        for _ in range(max(3, p // 16)):
            t, i, j = (int(rng.integers(m)), int(rng.integers(n)),
                       int(rng.integers(p)))
            Xc[t, i, j] = np.inf if rng.integers(2) else -np.inf
    elif kind == "outlier":
        Xc *= outlier_scale
        yc *= outlier_scale
    else:
        raise ValueError(f"unknown batch fault kind '{kind}' "
                         f"(want one of {BATCH_FAULT_KINDS})")
    return (jnp.asarray(Xc, X.dtype), jnp.asarray(yc, y.dtype))


# -- refit divergence ------------------------------------------------------

class DivergenceInjector:
    """Forces the next N refit attempts of a service to produce a
    NaN-poisoned candidate, via the `_refit_impl` seam.

    The real refit still runs (warm-start bookkeeping, generation
    bump on the candidate) — only its OUTPUT model fields are poisoned,
    so the rollback path under test sees exactly what a numerically
    diverged solve would hand it.

        inj = DivergenceInjector(svc)
        inj.arm(2)          # next two attempts diverge
        ...
        inj.uninstall()     # restore the pristine impl
    """

    def __init__(self, service):
        self.service = service
        self._orig = service._refit_impl
        self.calls = 0
        self.injected = 0
        self._armed = 0
        service._refit_impl = self._wrapped

    def arm(self, n: int = 1) -> None:
        self._armed += int(n)

    def uninstall(self) -> None:
        self.service._refit_impl = self._orig

    def _wrapped(self, state, lam, mu, Lam, *, lasso_iters, debias_iters,
                 warm, **kw):
        self.calls += 1
        candidate, info = self._orig(state, lam, mu, Lam,
                                     lasso_iters=lasso_iters,
                                     debias_iters=debias_iters, warm=warm,
                                     **kw)
        if self._armed > 0:
            self._armed -= 1
            self.injected += 1
            nan = jnp.full_like(candidate.beta_tilde, jnp.nan)
            candidate = candidate._replace(
                beta_local=jnp.full_like(candidate.beta_local, jnp.nan),
                beta_tilde=nan)
        return candidate, info


# -- torn writes -----------------------------------------------------------

def truncate_file(path: str, *, keep_fraction: float = 0.5) -> int:
    """Chop the tail off `path` in place (a simulated torn write).
    Returns the number of bytes kept. `keep_fraction=0` empties it."""
    import os
    size = os.path.getsize(path)
    keep = int(size * keep_fraction)
    with open(path, "r+b") as f:
        f.truncate(keep)
    return keep
