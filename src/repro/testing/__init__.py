"""Test-support utilities shipped inside the package.

`repro.testing.faults` is the deterministic fault-injection harness the
chaos tier (`tests/test_chaos.py`, `tools/chaos.py`) drives;
`repro.testing.interleave` is the deterministic thread-interleaving
harness the concurrency contract tier (`tests/test_interleave.py`,
DESIGN.md §17) drives. Both live under `src/` (not `tests/`) so
out-of-tree consumers can chaos-test and race-test their own
deployments of the streaming service.
"""
from repro.testing.faults import (
    FaultEvent, FaultSchedule, DivergenceInjector, apply_batch_fault,
    build_schedule, make_clean_batch, truncate_file,
)
from repro.testing.interleave import Gates, InterleaveScheduler, instrument

__all__ = [
    "FaultEvent", "FaultSchedule", "DivergenceInjector",
    "apply_batch_fault", "build_schedule", "make_clean_batch",
    "truncate_file",
    "Gates", "InterleaveScheduler", "instrument",
]
