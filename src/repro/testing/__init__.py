"""Test-support utilities shipped inside the package.

`repro.testing.faults` is the deterministic fault-injection harness the
chaos tier (`tests/test_chaos.py`, `tools/chaos.py`) drives; it lives
under `src/` (not `tests/`) so out-of-tree consumers can chaos-test
their own deployments of the streaming service.
"""
from repro.testing.faults import (
    FaultEvent, FaultSchedule, DivergenceInjector, apply_batch_fault,
    build_schedule, make_clean_batch, truncate_file,
)

__all__ = [
    "FaultEvent", "FaultSchedule", "DivergenceInjector",
    "apply_batch_fault", "build_schedule", "make_clean_batch",
    "truncate_file",
]
