"""Deterministic interleaving harness: the dynamic half of the
concurrency contract (DESIGN.md §17).

The static checker (repro_lint Engine 3, RL4xx) proves code *honors*
its declared `_SYNC_POLICY`; this module proves the policies are the
*right* ones, by forcing the thread schedules a production box would
only hit under load. Two instruments, pure stdlib, no jax:

* **`InterleaveScheduler`** — a seeded cooperative scheduler. Threads
  `register()` and then call `yield_point(tag)` at interesting moments;
  each yield hands the "token" to a seeded-RNG-chosen registered thread
  and blocks until the token comes back. Running the same seed replays
  the same schedule bit-for-bit; sweeping seeds explores adversarial
  interleavings systematically instead of hoping the OS scheduler gets
  hostile. Threads that block in real primitives (joins, queue gets)
  while holding the token would deadlock a strict token ring, so a
  blocked handoff self-reclaims after `max_wait_s` (counted in
  `stalls` — determinism of the *replayed decisions* is preserved; the
  reclaim only un-wedges threads the harness cannot see inside).

* **`Gates`** — named rendezvous points for fully scripted schedules.
  A thread calls `reach(name)` and parks; the test calls
  `wait_reached(name)` to know it is parked and `release(name)` to let
  it through. Where the seeded scheduler explores, gates *pin*: the
  pre-fix `ServingFront.stop()` race regression replays one exact
  schedule with no randomness at all.

* **`instrument(cls, attrs, scheduler)`** — subclass `cls` so that
  every get/set of the named attributes passes through a scheduler
  yield point. This plants context switches exactly at the shared-state
  touches the static checker reasons about, without editing the class
  under test.
"""
from __future__ import annotations

import random
import threading
from typing import Dict, Iterable, List, Optional, Tuple, Type

__all__ = ["InterleaveScheduler", "Gates", "instrument"]


class InterleaveScheduler:
    """Seeded token-passing scheduler over registered threads.

    Exactly one registered thread "holds the token" (runs) at a time;
    `yield_point` donates it to a seeded-random registered thread
    (possibly itself) and waits for it back. `close()` releases
    everyone and turns every subsequent yield into a no-op, so tests
    can fall back to real concurrency for cleanup joins.
    """

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_threads": "lock:_lock",
        "_active": "lock:_lock",
        "preemptions": "lock:_lock",
        "stalls": "lock:_lock",
        "schedule": "lock:_lock",
    }

    def __init__(self, seed: int, *, max_wait_s: float = 0.1,
                 auto_register: bool = True):
        self._rng = random.Random(seed)
        self.seed = seed
        self.max_wait_s = float(max_wait_s)
        self.auto_register = bool(auto_register)
        self._lock = threading.Lock()
        self._threads: Dict[int, threading.Event] = {}
        self._active = True
        self.preemptions = 0     # yields that handed the token away
        self.stalls = 0          # reclaims from a blocked token holder
        self.schedule: List[Tuple[str, int]] = []  # (tag, chosen ident)

    def register(self, thread: Optional[threading.Thread] = None) -> None:
        """Enroll a thread (default: the calling one) in the token
        ring. Unregistered threads run freely, un-scheduled."""
        ident = thread.ident if thread is not None \
            else threading.get_ident()
        if ident is None:
            raise ValueError("register() needs a started thread")
        with self._lock:
            self._threads.setdefault(ident, threading.Event())

    def unregister(self) -> None:
        with self._lock:
            self._threads.pop(threading.get_ident(), None)

    def yield_point(self, tag: str = "") -> None:
        """Donate the token to a seeded-random registered thread and
        wait for it back. No-op once closed or for lone threads."""
        me = threading.get_ident()
        with self._lock:
            if not self._active:
                return
            if me not in self._threads:
                if not self.auto_register:
                    return
                self._threads.setdefault(me, threading.Event())
            others = [i for i in self._threads if i != me]
            if not others:
                return
            chosen = self._rng.choice(others)
            self.schedule.append((tag, chosen))
            self.preemptions += 1
            my_ev = self._threads[me]
            my_ev.clear()
            self._threads[chosen].set()
        # wait for the token back; a holder blocked inside a real
        # primitive (join, queue get) can't donate, so reclaim after
        # max_wait_s rather than deadlocking the ring
        if not my_ev.wait(self.max_wait_s):
            with self._lock:
                if self._active:
                    self.stalls += 1

    def close(self) -> None:
        """End scheduling: wake every parked thread, make every further
        yield a no-op. Call before cleanup joins."""
        with self._lock:
            self._active = False
            for ev in self._threads.values():
                ev.set()


class Gates:
    """Named scripted rendezvous: `reach` parks, `release` frees.

    Each gate is a semaphore (starts at 0) plus an arrival event, so a
    test can both *know* a thread is parked at a named point and decide
    exactly when it proceeds — the fully deterministic complement to
    the seeded scheduler."""

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_gates": "lock:_lock",
    }

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._gates: Dict[str, Tuple[threading.Event,
                                     threading.Semaphore]] = {}

    def _gate(self, name: str) -> Tuple[threading.Event,
                                        threading.Semaphore]:
        with self._lock:
            if name not in self._gates:
                self._gates[name] = (threading.Event(),
                                     threading.Semaphore(0))
            return self._gates[name]

    def reach(self, name: str, timeout: Optional[float] = 10.0) -> None:
        """Park at `name` until the test `release()`s it."""
        arrived, sem = self._gate(name)
        arrived.set()
        if not sem.acquire(timeout=timeout):
            raise TimeoutError(f"gate '{name}' never released")

    def wait_reached(self, name: str, timeout: float = 10.0) -> None:
        """Block until some thread is parked at (or has passed) `name`."""
        arrived, _ = self._gate(name)
        if not arrived.wait(timeout):
            raise TimeoutError(f"no thread reached gate '{name}'")

    def release(self, name: str, n: int = 1) -> None:
        _, sem = self._gate(name)
        for _ in range(n):
            sem.release()


def instrument(cls: Type, attrs: Iterable[str],
               scheduler: InterleaveScheduler) -> Type:
    """Subclass `cls` with scheduler yield points on every get/set of
    the named attributes — context switches forced exactly at the
    shared-state touches the static checker (RL4xx) reasons about."""
    watched = frozenset(attrs)

    def __getattribute__(self, name):
        if name in watched:
            scheduler.yield_point(f"get:{name}")
        return object.__getattribute__(self, name)

    def __setattr__(self, name, value):
        if name in watched:
            scheduler.yield_point(f"set:{name}")
        object.__setattr__(self, name, value)

    return type(f"Interleaved{cls.__name__}", (cls,), {
        "__getattribute__": __getattribute__,
        "__setattr__": __setattr__,
    })
