"""Substrate: version-portable distributed/compat layer.

Single import point for everything that historically broke across jax
releases (shard_map location and kwargs, ambient-mesh context, mesh
construction) plus the host-device-count and subprocess-probe plumbing
shared by tests and benchmarks.
"""
from repro.substrate.collectives import (
    all_gather_tasks, all_to_all_experts, psum_stats,
)
from repro.substrate.compat import make_mesh, shard_map, use_mesh
from repro.substrate.feed import chunk_specs, feed_chunk, feed_shards
from repro.substrate.hostenv import force_host_device_count, host_device_env
from repro.substrate.mesh import data_model_mesh, data_task_mesh, task_mesh
from repro.substrate.probes import REPO_ROOT, popen_probe, run_probe

__all__ = [
    "all_gather_tasks", "all_to_all_experts", "psum_stats",
    "make_mesh", "shard_map", "use_mesh",
    "chunk_specs", "feed_chunk", "feed_shards",
    "force_host_device_count", "host_device_env",
    "data_model_mesh", "data_task_mesh", "task_mesh",
    "REPO_ROOT", "popen_probe", "run_probe",
]
