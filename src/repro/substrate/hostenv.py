"""Host-platform device-count setup (CPU SPMD testing).

The `--xla_force_host_platform_device_count=N` flag must reach XLA
before the backend initializes; previously every test/benchmark probe
re-spelled the `os.environ["XLA_FLAGS"]` incantation by hand. The
helpers here centralize it, both for the current process (call before
the first device query) and for subprocess environments.

This module deliberately does not import jax at module scope beyond the
lazy check in `force_host_device_count`.
"""
from __future__ import annotations

import os
from typing import Mapping, MutableMapping

_FLAG = "--xla_force_host_platform_device_count"


def _merge_xla_flags(existing: str, n: int) -> str:
    flags = [f for f in existing.split() if not f.startswith(_FLAG + "=")]
    flags.append(f"{_FLAG}={n}")
    return " ".join(flags)


def force_host_device_count(n: int, env: MutableMapping[str, str] | None = None) -> None:
    """Set XLA_FLAGS so the host platform exposes `n` devices.

    With `env=None` this mutates `os.environ` for the current process;
    it must run before jax initializes a backend (raises if too late and
    the count would change).
    """
    target = os.environ if env is None else env
    target["XLA_FLAGS"] = _merge_xla_flags(target.get("XLA_FLAGS", ""), n)
    if env is None:
        # Best-effort too-late detection. The only "is the backend up"
        # probe is private (and has moved before), so degrade to a
        # silent no-check on jax versions where it is absent rather
        # than break the very compat layer this module belongs to.
        try:
            from jax._src import xla_bridge
            initialized = xla_bridge.backends_are_initialized()
        except Exception:
            from repro import obs
            obs.inc("substrate.hostenv.init_probe_unavailable")
            return
        if initialized:
            import jax
            if jax.device_count() != n:
                raise RuntimeError(
                    f"jax backend already initialized with "
                    f"{jax.device_count()} devices; "
                    f"force_host_device_count({n}) must run first")


def host_device_env(n: int, extra_pythonpath: str | None = None,
                    base: Mapping[str, str] | None = None) -> dict:
    """Environment dict for a subprocess that needs `n` host devices.

    Merges XLA_FLAGS into a copy of `base` (default: os.environ) and
    optionally prepends `extra_pythonpath` to PYTHONPATH.
    """
    env = dict(os.environ if base is None else base)
    force_host_device_count(n, env)
    if extra_pythonpath:
        prev = env.get("PYTHONPATH", "")
        env["PYTHONPATH"] = extra_pythonpath + (os.pathsep + prev if prev else "")
    return env
