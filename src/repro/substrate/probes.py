"""Subprocess probe runner for multi-device CPU tests and benchmarks.

Several tests/benchmarks verify SPMD properties (collective counts,
8-device numerical equality) in a fresh process so the parent keeps its
single-CPU jax runtime. They all need the same boilerplate — XLA_FLAGS
before jax init, `src` on PYTHONPATH, a timeout — which used to be
copy-pasted into every probe string. `run_probe` owns it.
"""
from __future__ import annotations

import os
import subprocess
import sys

from repro.substrate.hostenv import host_device_env

# repo root = parent of the `src` directory this package lives in
_SRC = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))
REPO_ROOT = os.path.dirname(_SRC)


def run_probe(payload: str, *, n_devices: int = 8, timeout: int = 900,
              cwd: str | None = None) -> subprocess.CompletedProcess:
    """Run `payload` (python source) in a subprocess with `n_devices`
    forced host devices and `src` importable. Returns the completed
    process (check `returncode` / parse `stdout` yourself)."""
    env = host_device_env(n_devices, extra_pythonpath=_SRC)
    return subprocess.run([sys.executable, "-c", payload],
                          capture_output=True, text=True,
                          cwd=cwd or REPO_ROOT, timeout=timeout, env=env)


def popen_probe(payload: str, *, n_devices: int = 8,
                cwd: str | None = None) -> subprocess.Popen:
    """`run_probe` that returns the LIVE `Popen` instead of waiting.

    The chaos tier uses this to kill a probe mid-flight (SIGKILL while
    it is mid-ingest) and then assert the parent-side artifacts — a
    crash-safe checkpoint directory, say — survived the abrupt death.
    The caller owns the process: `communicate()`/`kill()`/`wait()` it.
    Same environment contract as `run_probe` (forced host devices,
    `src` importable, repo-root cwd), stdout/stderr piped as text.
    """
    env = host_device_env(n_devices, extra_pythonpath=_SRC)
    return subprocess.Popen([sys.executable, "-c", payload],
                            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                            text=True, cwd=cwd or REPO_ROOT, env=env)
