"""Version-portable jax distributed API resolution.

jax has moved `shard_map` twice (`jax.experimental.shard_map` ->
`jax.shard_map`) and renamed its replication-check kwarg
(`check_rep` -> `check_vma`); the ambient-mesh context manager has
likewise wandered (`Mesh.__enter__` -> `jax.sharding.use_mesh` ->
`jax.set_mesh`). Every caller in this repo goes through the resolvers
here instead of hard-coding one vintage of the API.

Nothing in this module touches jax device state at import time, so it is
safe to import before `force_host_device_count` (see `hostenv.py`).
"""
from __future__ import annotations

import contextlib
import inspect
from typing import Any, Callable

import jax


def _resolve_shard_map() -> Callable[..., Any]:
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental.shard_map import shard_map as sm  # jax <= 0.5
    return sm


_RAW_SHARD_MAP = _resolve_shard_map()
# name of the replication-check kwarg on the installed jax, if any
_CHECK_KW = next(
    (kw for kw in ("check_vma", "check_rep")
     if kw in inspect.signature(_RAW_SHARD_MAP).parameters),
    None,
)


def shard_map(f: Callable, *, mesh, in_specs, out_specs, check: bool = False):
    """`jax.shard_map` with the replication check spelled portably.

    `check=False` maps to `check_vma=False` on new jax and
    `check_rep=False` on 0.4.x/0.5.x; the kwarg is omitted entirely on a
    jax that dropped it.
    """
    kwargs = {_CHECK_KW: check} if _CHECK_KW is not None else {}
    return _RAW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


def make_mesh(shape, axis_names):
    """`jax.make_mesh` where available, mesh_utils otherwise."""
    mk = getattr(jax, "make_mesh", None)
    if mk is not None:
        return mk(tuple(shape), tuple(axis_names))
    from jax.experimental import mesh_utils
    devices = mesh_utils.create_device_mesh(tuple(shape))
    return jax.sharding.Mesh(devices, tuple(axis_names))


@contextlib.contextmanager
def use_mesh(mesh):
    """Ambient-mesh context manager across jax versions.

    Prefers `jax.set_mesh` / `jax.sharding.use_mesh`; falls back to the
    legacy `with mesh:` block on 0.4.x.
    """
    setter = getattr(jax, "set_mesh", None) or \
        getattr(jax.sharding, "use_mesh", None)
    if setter is not None:
        with setter(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh
