"""Thin collective helpers used inside shard_map bodies.

These exist so algorithm code states *what* it communicates (gather the
per-task rows, one round) rather than which jax.lax spelling this
version supports.

Every helper also feeds the telemetry byte ledger
(`collective.calls` / `collective.bytes` counters, tagged by op and
axis) so `benchmarks/communication.py` reports bytes the program
actually moved rather than a hand-maintained formula. The accounting
runs at TRACE time — these helpers execute inside shard_map tracing —
so the counts are per compilation, and the byte model is
local-shard nbytes × mesh-axis participants (what each device puts on
the wire for a ring collective of k shards). `jax.lax.psum(1, axis)`
on a Python int is concrete at trace time and emits no HLO, so the
participant lookup never perturbs the compiled program (the HLO probe
in benchmarks/communication.py pins this).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs


def _record(op: str, x, axis: str) -> None:
    if not obs.enabled():
        return
    try:
        k = int(jax.lax.psum(1, axis))
    except Exception:
        k = 0       # axis not bound (helper called outside shard_map)
        obs.inc("collective.axis_unbound", op=op, axis=axis)
    nbytes = int(x.size) * x.dtype.itemsize
    obs.inc("collective.calls", op=op, axis=axis)
    obs.inc("collective.bytes", k * nbytes, op=op, axis=axis)


def all_gather_tasks(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Gather shards along mesh `axis`, concatenated on dim 0 (tiled)."""
    _record("all_gather_tasks", x, axis)
    return jax.lax.all_gather(x, axis, tiled=True)


def all_to_all_experts(x: jnp.ndarray, axis: str, *, split_axis: int = 0,
                       concat_axis: int = 0) -> jnp.ndarray:
    """all_to_all over mesh `axis` (MoE dispatch/return)."""
    _record("all_to_all_experts", x, axis)
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis, tiled=False)


def psum_stats(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Sum partial sufficient statistics over mesh `axis`.

    The streaming accumulator computes per-device partial (Sigma, c)
    sums over the minibatch rows it owns and reduces them here — the
    additive-stats property is what makes engine-level SPMD a single
    psum instead of gathering raw samples.
    """
    _record("psum_stats", x, axis)
    return jax.lax.psum(x, axis)
