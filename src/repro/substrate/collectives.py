"""Thin collective helpers used inside shard_map bodies.

These exist so algorithm code states *what* it communicates (gather the
per-task rows, one round) rather than which jax.lax spelling this
version supports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def all_gather_tasks(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Gather shards along mesh `axis`, concatenated on dim 0 (tiled)."""
    return jax.lax.all_gather(x, axis, tiled=True)


def all_to_all_experts(x: jnp.ndarray, axis: str, *, split_axis: int = 0,
                       concat_axis: int = 0) -> jnp.ndarray:
    """all_to_all over mesh `axis` (MoE dispatch/return)."""
    return jax.lax.all_to_all(x, axis, split_axis, concat_axis, tiled=False)


def psum_stats(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Sum partial sufficient statistics over mesh `axis`.

    The streaming accumulator computes per-device partial (Sigma, c)
    sums over the minibatch rows it owns and reduces them here — the
    additive-stats property is what makes engine-level SPMD a single
    psum instead of gathering raw samples.
    """
    return jax.lax.psum(x, axis)
