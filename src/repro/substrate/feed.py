"""Feeding streaming minibatches onto a `data x task` mesh.

The sharded ingest worker (`stream.accumulate`) expects its chunk
already laid out as `P(task, data, None)` / `P(task, data)`. How the
rows GET there is a substrate concern, and there are two distinct
paths:

* **`feed_chunk`** — the single-controller path: one resident host
  array placed with `jax.device_put(x, NamedSharding(...))`. The
  runtime splits the transfer per device; this is the right call when
  the whole chunk already lives on the ingest host (tests, benchmarks,
  single-node deployments).

* **`feed_shards`** — the multi-host idiom: each ingest worker hands
  over only ITS rows (`(m, n_local, p)` blocks along the data axis),
  each block is `device_put` onto its own device addressable from this
  process, and `jax.make_array_from_single_device_arrays` assembles
  the global array without the rows ever being concatenated on any
  single host. On one process this runs the same per-shard protocol
  over local devices — which is exactly what the multi-host tests can
  exercise under a forced 8-device CPU topology.

Both return arrays the compiled accumulator consumes with zero
resharding (its `in_specs` match), so ingest cost stays the local
einsum plus one psum. Byte accounting goes through eager `obs`
counters (`substrate.feed.bytes`), never from traced code (RL108).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs


def chunk_specs(data_axis: str = "data",
                task_axis: str = "task") -> Tuple[P, P]:
    """The (X, y) partition specs the sharded accumulator ingests:
    tasks over `task_axis`, rows over `data_axis`, features replicated."""
    return (P(task_axis, data_axis, None), P(task_axis, data_axis))


def _record_feed(nbytes: int, path: str) -> None:
    if obs.enabled():
        obs.inc("substrate.feed.bytes", nbytes, path=path)
        obs.inc("substrate.feed.chunks", path=path)


def feed_chunk(X: jnp.ndarray, y: jnp.ndarray, mesh: Mesh,
               data_axis: str = "data", task_axis: str = "task"
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Place one host-resident chunk X (m, n, p) / y (m, n) onto `mesh`
    in the accumulator's layout. Requires m and n divisible by the
    respective mesh axis sizes (the accumulator's own contract)."""
    spec_X, spec_y = chunk_specs(data_axis, task_axis)
    Xd = jax.device_put(X, NamedSharding(mesh, spec_X))
    yd = jax.device_put(y, NamedSharding(mesh, spec_y))
    _record_feed(Xd.nbytes + yd.nbytes, "chunk")
    return Xd, yd


def feed_shards(X_shards: Sequence, y_shards: Sequence, mesh: Mesh,
                data_axis: str = "data", task_axis: str = "task"
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Assemble a global chunk from per-worker row blocks.

    `X_shards[i]` is worker i's rows (m, n_i, p) (equal n_i across
    workers), ordered along the `data_axis`; `y_shards[i]` the matching
    (m, n_i). Each block is split over the task axis, `device_put` onto
    the device owning that (data, task) coordinate, and the global
    (m, n_total, p) array is assembled from the single-device pieces —
    no host ever holds the concatenated chunk. The result is sharded
    exactly like `feed_chunk`'s.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_data, n_task = axis_sizes[data_axis], axis_sizes[task_axis]
    if len(X_shards) != n_data or len(y_shards) != n_data:
        raise ValueError(
            f"got {len(X_shards)} row blocks for a mesh with "
            f"{n_data} '{data_axis}' slots (one block per slot)")
    m, n_local, p = X_shards[0].shape
    if m % n_task:
        raise ValueError(f"m={m} tasks not divisible by "
                         f"{task_axis}={n_task}")
    m_local = m // n_task
    spec_X, spec_y = chunk_specs(data_axis, task_axis)
    sharding_X = NamedSharding(mesh, spec_X)
    sharding_y = NamedSharding(mesh, spec_y)
    # device owning (data=d, task=t) in the mesh's device grid; the
    # mesh axes may be in either order, so index by name
    ax = {name: i for i, name in enumerate(mesh.axis_names)}

    def dev(d: int, t: int):
        idx = [0, 0]
        idx[ax[data_axis]] = d
        idx[ax[task_axis]] = t
        return mesh.devices[tuple(idx)]

    pieces_X, pieces_y = [], []
    nbytes = 0
    for d in range(n_data):
        Xb, yb = jnp.asarray(X_shards[d]), jnp.asarray(y_shards[d])
        if Xb.shape != (m, n_local, p) or yb.shape != (m, n_local):
            raise ValueError(
                f"row block {d} has shape {Xb.shape}/{yb.shape}; every "
                f"block must be ({m}, {n_local}, {p})/({m}, {n_local})")
        for t in range(n_task):
            rows = slice(t * m_local, (t + 1) * m_local)
            px = jax.device_put(Xb[rows], dev(d, t))
            py = jax.device_put(yb[rows], dev(d, t))
            nbytes += px.nbytes + py.nbytes
            pieces_X.append(px)
            pieces_y.append(py)
    n_total = n_local * n_data
    Xg = jax.make_array_from_single_device_arrays(
        (m, n_total, p), sharding_X, pieces_X)
    yg = jax.make_array_from_single_device_arrays(
        (m, n_total), sharding_y, pieces_y)
    _record_feed(nbytes, "shards")
    return Xg, yg
