"""Mesh construction helpers for the task-parallel DSML layer."""
from __future__ import annotations

import jax

from repro.substrate.compat import make_mesh


def task_mesh(n_tasks: int | None = None, axis: str = "task"):
    """1-D mesh over `n_tasks` devices (default: all local devices)."""
    n = len(jax.devices()) if n_tasks is None else n_tasks
    return make_mesh((n,), (axis,))


def data_model_mesh(model_axis: int = 1):
    """2-D (data, model) mesh over whatever devices exist."""
    n = len(jax.devices())
    return make_mesh((n // model_axis, model_axis), ("data", "model"))
