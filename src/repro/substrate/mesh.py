"""Mesh construction helpers for the task-parallel DSML layer."""
from __future__ import annotations

import jax

from repro.substrate.compat import make_mesh


def task_mesh(n_tasks: int | None = None, axis: str = "task"):
    """1-D mesh over `n_tasks` devices (default: all local devices)."""
    n = len(jax.devices()) if n_tasks is None else n_tasks
    return make_mesh((n,), (axis,))


def data_model_mesh(model_axis: int = 1):
    """2-D (data, model) mesh over whatever devices exist."""
    n = len(jax.devices())
    return make_mesh((n // model_axis, model_axis), ("data", "model"))


def data_task_mesh(n_task: int = 1, n_data: int | None = None,
                   axes: tuple[str, str] = ("data", "task")):
    """2-D (data, task) mesh for the streaming layer: minibatch rows are
    sharded over `data` and reduced with one psum; tasks stay sharded
    over `task` (default: all remaining devices go to `data`)."""
    if n_data is None:
        n_data = len(jax.devices()) // n_task
    return make_mesh((n_data, n_task), axes)
