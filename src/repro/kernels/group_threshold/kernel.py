"""Pallas TPU kernel: row-wise group hard threshold.

The (p, m) matrix is tiled (BP, m) — m (tasks) is small, so whole rows
sit in VMEM and each grid step reduces its rows' squared norms on the
VPU, compares against Lambda^2 (avoiding the sqrt), and writes both the
masked rows and the int8 support indicator.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gt_kernel(lam_ref, b_ref, out_ref, keep_ref):
    b = b_ref[...].astype(jnp.float32)
    sq = jnp.sum(b * b, axis=1, keepdims=True)        # (bp, 1)
    lam2 = lam_ref[0] * lam_ref[0]
    keep = sq > lam2
    out_ref[...] = jnp.where(keep, b, 0.0).astype(out_ref.dtype)
    keep_ref[...] = keep.astype(jnp.int8)


@functools.partial(jax.jit, static_argnames=("bp", "interpret"))
def group_threshold_pallas(B, Lam, *, bp: int = 256, interpret: bool = False):
    """B: (p, m). Returns (filtered (p, m), keep (p, 1) int8)."""
    p, m = B.shape
    bp = min(bp, p)
    assert p % bp == 0, (p, bp)
    lam_arr = jnp.full((1,), Lam, jnp.float32)
    return pl.pallas_call(
        _gt_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bp, m), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp, m), lambda i: (i, 0)),
            pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, m), B.dtype),
            jax.ShapeDtypeStruct((p, 1), jnp.int8),
        ],
        interpret=interpret,
    )(lam_arr, B)
