"""Pure-jnp oracle for group hard thresholding (paper eq. (5)-(6)).

B: (p, m) stacked debiased estimates (variables x tasks). Returns the
filtered matrix and the support indicator:
    keep_j = ||B_j||_2 > Lambda ;  out_j = B_j * keep_j
"""
from __future__ import annotations

import jax.numpy as jnp


def group_threshold_ref(B: jnp.ndarray, Lam) -> tuple[jnp.ndarray, jnp.ndarray]:
    norms = jnp.sqrt(jnp.sum(B.astype(jnp.float32) ** 2, axis=-1))
    keep = norms > Lam
    return (B * keep[:, None].astype(B.dtype)), keep
