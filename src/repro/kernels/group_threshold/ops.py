"""Dispatcher for the group hard-threshold kernel (master step of DSML).

Same convention as the solver/sample-streaming kernels
(`kernels/*/ops.py`): the pallas kernel on tile-able shapes (interpret
mode off-TPU so the same BlockSpecs execute everywhere), the jnp oracle
on ragged or sliver-degraded ones — the op is exact per row, so routing
never perturbs the filtered matrix or the support indicator. `block=`
is validated through `common.validate_block` (the seed-era wrapper
halved a hard-coded 256 with no validation at all) and clipped with
`aligned_fit_block`, the same notion of "legal tile" every other
dispatcher judges by.
"""
from __future__ import annotations

from repro.kernels.common import (
    aligned_fit_block, degrades_to_slivers, on_tpu, record_route,
    validate_block,
)
from repro.kernels.group_threshold.kernel import group_threshold_pallas
from repro.kernels.group_threshold.ref import group_threshold_ref


def resolve_group_block(p: int, block=None) -> int:
    """Normalize a block policy to a concrete row-tile size bp. `block`
    is None (the historical 256 request) or an int bp request, clipped
    to the largest 8-ALIGNED divisor of p (the sublane axis of the
    (bp, m) tile — m tasks ride the lane axis whole)."""
    (bp,) = validate_block(256 if block is None else block, 1, "(bp,)")
    return aligned_fit_block(p, bp)


def group_routes_to_oracle(p: int, block=None) -> bool:
    """Routing predicate: ragged row counts (p % 8) and row tiles that
    degrade to slivers against the request (e.g. p = 1016 = 8*127, where
    the seed-era halving loop quietly ran an 8-row sliver sweep) take
    the jnp oracle. Validates `block` on every path."""
    (bp_req,) = validate_block(256 if block is None else block, 1, "(bp,)")
    return bool(p % 8) or degrades_to_slivers(p, bp_req)


def group_threshold(B, Lam, *, block=None, interpret: bool | None = None):
    """Row-wise group hard threshold. B: (p, m) -> (filtered (p, m),
    keep (p,) bool). `block` is None or an int row tile bp."""
    p, m = B.shape
    bp = resolve_group_block(p, block)
    interp = (not on_tpu()) if interpret is None else interpret
    if group_routes_to_oracle(p, block):
        record_route("group_threshold", "ragged" if p % 8 else "sliver",
                     blocks=(bp,))
        out, keep = group_threshold_ref(B, Lam)
        return out, keep
    record_route("group_threshold", None, blocks=(bp,))
    out, keep = group_threshold_pallas(B, Lam, bp=bp, interpret=interp)
    return out, keep[:, 0].astype(bool)
