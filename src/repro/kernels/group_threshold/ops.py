"""Jit'd wrapper for the group-threshold kernel (master step of DSML)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.group_threshold.kernel import group_threshold_pallas
from repro.kernels.group_threshold.ref import group_threshold_ref


def group_threshold(B, Lam, *, interpret: bool | None = None):
    """B: (p, m) -> (filtered (p, m), keep (p,) bool)."""
    p, m = B.shape
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    if p % 8:
        out, keep = group_threshold_ref(B, Lam)
        return out, keep
    bp = 256
    while p % bp:
        bp //= 2
    out, keep = group_threshold_pallas(B, Lam, bp=bp, interpret=interp)
    return out, keep[:, 0].astype(bool)
