"""Shared routing/clipping helpers for the sample-streaming kernel
dispatchers (`logistic_grad`, `rank_update`). One definition site so
the dispatchers — and the engine block policies built on them — can
never desync.
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fit_block(size: int, block: int) -> int:
    """Largest divisor of `size` that is <= `block` — the legal tile
    closest to the requested one. (NOT the halving loop of the older
    ista dispatcher: halving a non-divisor request like 48 against
    size 80 bottoms out at 1 and silently degrades the grid to
    single-element tiles; the divisor scan returns 40.)"""
    b = min(block, size)
    while size % b:
        b -= 1
    return b


def is_ragged_samples(n: int, p: int) -> bool:
    """THE routing predicate for the sample-streaming kernels (logistic
    gradient, rank-n update): shapes whose sample or feature axis the
    TPU tiling cannot legally cover go to the jnp oracle. Shared with
    the engine's block policies so the two can never desync."""
    return bool(n % 8 or p % 8)
