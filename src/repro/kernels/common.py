"""Shared routing/clipping helpers for the sample-streaming kernel
dispatchers (`logistic_grad`, `rank_update`). One definition site so
the dispatchers — and the engine block policies built on them — can
never desync.
"""
from __future__ import annotations

import jax

from repro import obs


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fit_block(size: int, block: int) -> int:
    """Largest divisor of `size` that is <= `block` — the legal tile
    closest to the requested one. (NOT the halving loop of the older
    ista dispatcher: halving a non-divisor request like 48 against
    size 80 bottoms out at 1 and silently degrades the grid to
    single-element tiles; the divisor scan returns 40.)"""
    b = min(block, size)
    while size % b:
        b -= 1
    return b


# the minimum tile worth running a grid over: when an axis has no
# 8-aligned divisor at or above this (relative to the request), the
# best tile a TPU grid could legally use is a sliver and the grid it
# implies is quietly catastrophic — e.g. size 1016 = 8 * 127 against a
# 128 request: the divisor scan finds 127 (which breaks the 8-row
# sublane alignment) and the best ALIGNED divisor is 8, a 127-step
# sliver sweep where the caller asked for ~8 steps of 128
MIN_TILE = 32


def aligned_fit_block(size: int, block: int) -> int:
    """Largest divisor of `size` that is <= `block` AND keeps the TPU's
    8-row alignment (the tile the hardware grid could actually use).
    Falls back to the plain divisor scan when the axis itself is not
    8-aligned (such shapes are ragged and never reach a kernel)."""
    if size % 8 or block < 8:
        return fit_block(size, block)
    return 8 * fit_block(size // 8, block // 8)


def validate_block(block, arity: int, doc: str, *,
                   arities: tuple | None = None) -> tuple:
    """Shared `block=`-argument validation for ALL kernel dispatchers:
    anything that is not an accepted form — bools, floats, wrong-arity
    tuples — raises instead of being silently coerced (the historical
    `block[0]` bug let a rank-style pair tile the wrong axes). Entries
    must be POSITIVE — a zero block would divide-by-zero inside the
    divisor scan and a negative one would silently reroute to the
    oracle. `doc` names the expected tuple form in the error.

    Two acceptance modes, one definition site (so the lint tier has a
    single pattern to check — see tools/repro_lint):

    * `arities=None` (rank_update / ista_step / group / flash style):
      an int broadcasts to all `arity` axes, a tuple must have exactly
      `arity` entries.
    * `arities=(0, 1, arity)`-style (logistic style, dispatchers with
      budgeted per-axis defaults): 0 admits `block=None` (every axis
      defaulted), 1 admits a bare int as a FIRST-axis request (the
      remaining axes defaulted, NOT broadcast), `arity` admits the full
      tuple. The returned length-`arity` tuple pads defaulted axes with
      None for the resolver to budget.
    """
    def ok(b):
        return isinstance(b, int) and not isinstance(b, bool) and b >= 1
    if arities is None:
        if ok(block):
            return (block,) * arity
        if (isinstance(block, tuple) and len(block) == arity
                and all(ok(b) for b in block)):
            return block
    else:
        if block is None and 0 in arities:
            return (None,) * arity
        if ok(block) and 1 in arities:
            return (block,) + (None,) * (arity - 1)
        if (isinstance(block, tuple) and len(block) == arity
                and arity in arities and all(ok(b) for b in block)):
            return block
    raise TypeError(
        f"block must be a positive int or a {doc} tuple of positive "
        f"ints — got {block!r}")


def degrades_to_slivers(size: int, block: int) -> bool:
    """True when fitting the requested `block` to `size` degrades to a
    sliver tile: the largest aligned divisor falls below MIN_TILE AND
    below a quarter of the request (a >4x longer grid than asked for).
    Such shapes belong to the oracle — an explicitly tiny request, an
    axis that IS tiny, or a modest clip (48-on-80 -> 40) is honoured;
    only the silent collapse (128-on-1016 -> 8) is routed away."""
    return aligned_fit_block(size, block) < min(block // 4, size, MIN_TILE)


def is_ragged_samples(n: int, p: int) -> bool:
    """THE routing predicate for the sample-streaming kernels (logistic
    gradient, rank-n update): shapes whose sample or feature axis the
    TPU tiling cannot legally cover go to the jnp oracle. Shared with
    the engine's block policies so the two can never desync."""
    return bool(n % 8 or p % 8)


def record_route(kernel: str, reason: str | None, *, blocks=None) -> None:
    """THE telemetry funnel for dispatcher routing decisions — the one
    audited exception to lint code RL108 (no `repro.obs` calls in
    jit-reachable code). Dispatchers run at trace time under jit, so
    these counters count COMPILATIONS, not executions; every argument
    is a Python-concrete shape/policy value, never a tracer, which is
    why routing through here is safe where a raw obs call is not.

    `reason` is None on the kernel path, else why the oracle won
    (`ragged` / `sliver` / `vmem_budget` / `backend`); `blocks` is the
    resolved tile tuple."""
    if not obs.enabled():
        return
    obs.inc("dispatch.route", kernel=kernel,
            outcome="kernel" if reason is None else "oracle",
            reason=reason or "kernel",
            blocks="none" if blocks is None
            else "x".join(str(b) for b in blocks))
