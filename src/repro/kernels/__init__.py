# OPTIONAL layer. Add <name>.py (or .cu) + ops.py + ref.py ONLY
# for compute hot-spots the paper itself optimizes with a custom
# kernel. Leave this package empty if the paper has none.
#
# kernels/autotune.py is the shared block-size policy for the batched
# solver kernels: per-kernel-namespaced (backend, dims, dtype) winners
# (fista_step/, logistic_grad/, rank_update/), cached in-process and
# under the repo cache dir (DESIGN.md §10-§11).
