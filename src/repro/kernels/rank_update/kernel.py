"""Pallas TPU kernel: fused rank-n sufficient-statistics update.

One dispatch folds a raw sample chunk into both statistics every DSML
path consumes:

    Sigma = n^-1 X' W X,    c = n^-1 X' W y

for all m tasks — the streaming layer's always-on ingest hot loop and
the front of every batch fit. Tiling (DESIGN.md §11): the grid is
(m, ni, nj, nk) — the (p, p) covariance output is tiled (bp, bp) over
(i, j), and the contraction over samples runs innermost in `bn`-row
tiles with an f32 VMEM scratch accumulator, exactly the layout of the
batched ISTA kernel with samples as the contraction axis. The
correlation c shares the sweep instead of paying a second pass: its
(bp, 1) accumulator advances on the j == 0 column sweep (the same
weighted X tile `W X_i` feeds both MXU dots), and both epilogues scale
by 1/n (compile-time constant) on the last sample tile. The diagonal
weight W rides as a (bn, 1) column so the weighting is one VPU
broadcast-multiply per tile; `weights=None` compiles an unweighted
specialization with no W stream and no multiply (the always-on ingest
common case).

`sigma_only_pallas` / `c_only_pallas` are the UNFUSED halves — the
two-dispatch baseline the fused kernel is benchmarked against
(benchmarks/kernels_bench.py), which streams X twice.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rank_update_kernel(*refs, nk: int, inv_n: float, weighted: bool):
    # the unweighted specialization (the always-on ingest common case)
    # drops the w input stream and the per-tile broadcast multiply
    if weighted:
        (xi_ref, xj_ref, w_ref, y_ref, sig_ref, c_ref,
         sig_acc, c_acc) = refs
    else:
        xi_ref, xj_ref, y_ref, sig_ref, c_ref, sig_acc, c_acc = refs
    j = pl.program_id(2)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init_sig():
        sig_acc[...] = jnp.zeros_like(sig_acc)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_c():
        c_acc[...] = jnp.zeros_like(c_acc)

    xiw = xi_ref[0].astype(jnp.float32)
    if weighted:
        xiw = xiw * w_ref[0].astype(jnp.float32)
    sig_acc[...] += jnp.dot(xiw.T, xj_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _c_accum():
        c_acc[...] += jnp.dot(xiw.T, y_ref[0].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _sig_epilogue():
        sig_ref[0] = (inv_n * sig_acc[...]).astype(sig_ref.dtype)

    @pl.when(jnp.logical_and(j == 0, k == nk - 1))
    def _c_epilogue():
        c_ref[0] = (inv_n * c_acc[...]).astype(c_ref.dtype)


def _sigma_only_kernel(*refs, nk: int, inv_n: float, weighted: bool):
    if weighted:
        xi_ref, xj_ref, w_ref, sig_ref, sig_acc = refs
    else:
        xi_ref, xj_ref, sig_ref, sig_acc = refs
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        sig_acc[...] = jnp.zeros_like(sig_acc)

    xiw = xi_ref[0].astype(jnp.float32)
    if weighted:
        xiw = xiw * w_ref[0].astype(jnp.float32)
    sig_acc[...] += jnp.dot(xiw.T, xj_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        sig_ref[0] = (inv_n * sig_acc[...]).astype(sig_ref.dtype)


def _c_only_kernel(*refs, nk: int, inv_n: float, weighted: bool):
    if weighted:
        xi_ref, w_ref, y_ref, c_ref, c_acc = refs
    else:
        xi_ref, y_ref, c_ref, c_acc = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        c_acc[...] = jnp.zeros_like(c_acc)

    xiw = xi_ref[0].astype(jnp.float32)
    if weighted:
        xiw = xiw * w_ref[0].astype(jnp.float32)
    c_acc[...] += jnp.dot(xiw.T, y_ref[0].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        c_ref[0] = (inv_n * c_acc[...]).astype(c_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bp", "bn", "interpret"))
def rank_update_pallas(Xs, ys, weights=None, *, bp: int = 128,
                       bn: int = 128, interpret: bool = False):
    """Fused rank-n statistics update in ONE pallas call.

    Xs (m, n, p); ys and optional weights (m, n). Returns
    (Sigmas (m, p, p), cs (m, p)) = (n^-1 X'WX, n^-1 X'Wy) per task.
    `bp` tiles the feature axis (both covariance output dims), `bn` the
    contracted sample axis. `weights=None` compiles the unweighted
    specialization — no W input stream, no per-tile multiply — which is
    the always-on ingest common case.
    """
    m, n, p = Xs.shape
    bp = min(bp, p)
    bn = min(bn, n)
    assert p % bp == 0 and n % bn == 0, (m, n, p, bp, bn)
    ni = nj = p // bp
    nk = n // bn
    weighted = weights is not None
    xi_spec = pl.BlockSpec((1, bn, bp), lambda t, i, j, k: (t, k, i))
    xj_spec = pl.BlockSpec((1, bn, bp), lambda t, i, j, k: (t, k, j))
    col_spec = pl.BlockSpec((1, bn, 1), lambda t, i, j, k: (t, k, 0))
    w_ops = [weights[..., None]] if weighted else []
    Sig, cs = pl.pallas_call(
        functools.partial(_rank_update_kernel, nk=nk, inv_n=1.0 / n,
                          weighted=weighted),
        grid=(m, ni, nj, nk),
        in_specs=[xi_spec, xj_spec] + [col_spec] * (1 + weighted),
        out_specs=(
            pl.BlockSpec((1, bp, bp), lambda t, i, j, k: (t, i, j)),
            pl.BlockSpec((1, bp, 1), lambda t, i, j, k: (t, i, 0)),
        ),
        out_shape=(jax.ShapeDtypeStruct((m, p, p), Xs.dtype),
                   jax.ShapeDtypeStruct((m, p, 1), Xs.dtype)),
        scratch_shapes=[pltpu.VMEM((bp, bp), jnp.float32),
                        pltpu.VMEM((bp, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, Xs, *w_ops, ys[..., None])
    return Sig, cs[..., 0]


@functools.partial(jax.jit, static_argnames=("bp", "bn", "interpret"))
def rank_update_unfused_pallas(Xs, ys, weights=None, *, bp: int = 128,
                               bn: int = 128, interpret: bool = False):
    """The two-dispatch baseline: a covariance-only kernel plus a
    correlation-only kernel. Same tiles and arithmetic as the fused
    kernel (including the unweighted specialization), but X is streamed
    (and weighted) twice."""
    m, n, p = Xs.shape
    bp = min(bp, p)
    bn = min(bn, n)
    assert p % bp == 0 and n % bn == 0, (m, n, p, bp, bn)
    ni = nj = p // bp
    nk = n // bn
    weighted = weights is not None
    w_ops = [weights[..., None]] if weighted else []
    xi4 = pl.BlockSpec((1, bn, bp), lambda t, i, j, k: (t, k, i))
    xj4 = pl.BlockSpec((1, bn, bp), lambda t, i, j, k: (t, k, j))
    col4 = pl.BlockSpec((1, bn, 1), lambda t, i, j, k: (t, k, 0))
    Sig = pl.pallas_call(
        functools.partial(_sigma_only_kernel, nk=nk, inv_n=1.0 / n,
                          weighted=weighted),
        grid=(m, ni, nj, nk),
        in_specs=[xi4, xj4] + [col4] * weighted,
        out_specs=pl.BlockSpec((1, bp, bp), lambda t, i, j, k: (t, i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p, p), Xs.dtype),
        scratch_shapes=[pltpu.VMEM((bp, bp), jnp.float32)],
        interpret=interpret,
    )(Xs, Xs, *w_ops)
    xi3 = pl.BlockSpec((1, bn, bp), lambda t, i, k: (t, k, i))
    col3 = pl.BlockSpec((1, bn, 1), lambda t, i, k: (t, k, 0))
    cs = pl.pallas_call(
        functools.partial(_c_only_kernel, nk=nk, inv_n=1.0 / n,
                          weighted=weighted),
        grid=(m, ni, nk),
        in_specs=[xi3] + [col3] * (1 + weighted),
        out_specs=pl.BlockSpec((1, bp, 1), lambda t, i, k: (t, i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, p, 1), Xs.dtype),
        scratch_shapes=[pltpu.VMEM((bp, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, *w_ops, ys[..., None])
    return Sig, cs[..., 0]
