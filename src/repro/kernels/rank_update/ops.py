"""Dispatcher for the fused rank-n sufficient-statistics update.

Same convention as `kernels/ista_step/ops.py` and
`kernels/logistic_grad/ops.py`: pallas on MXU-friendly shapes
(interpret mode off-TPU), the jnp oracle on ragged shapes — and the
oracle is bitwise the historical `sufficient_stats` einsum pair, so the
CPU default path perturbs nothing downstream.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import fit_block, is_ragged_samples, on_tpu
from repro.kernels.rank_update.kernel import (
    rank_update_pallas, rank_update_unfused_pallas,
)
from repro.kernels.rank_update.ref import rank_update_ref


def resolve_rank_blocks(n: int, p: int, block) -> Tuple[int, int]:
    """Normalize a block policy to concrete (bp, bn) tile sizes.
    `block` is one int (applied to both axes) or an explicit (bp, bn)
    pair, e.g. an autotuned winner from `repro.kernels.autotune.
    autotune_rank_block`; each entry is clipped to the largest divisor
    of its dimension."""
    bp, bn = block if isinstance(block, tuple) else (block, block)
    return fit_block(p, bp), fit_block(n, bn)


def rank_update(Xs, ys, weights=None, *, block=128,
                interpret: bool | None = None,
                use_kernel: bool | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-task statistics (n^-1 X'WX, n^-1 X'Wy) for a sample chunk.

    Xs (m, n, p), ys (m, n), optional weights (m, n) ->
    (Sigmas (m, p, p), cs (m, p)). Routes to the fused pallas kernel on
    MXU-friendly shapes when `use_kernel` (default: only on TPU — the
    XLA einsum oracle is the fast CPU path); ragged shapes always take
    the oracle. `block` is an int or an explicit (bp, bn) pair.
    """
    m, n, p = Xs.shape
    if use_kernel is None:
        use_kernel = on_tpu()
    interp = (not on_tpu()) if interpret is None else interpret
    if not use_kernel or is_ragged_samples(n, p):
        return rank_update_ref(Xs, ys, weights)
    bp, bn = resolve_rank_blocks(n, p, block)
    return rank_update_pallas(Xs, ys, weights, bp=bp, bn=bn,
                              interpret=interp)


def rank_update_unfused(Xs, ys, weights=None, *, block=128,
                        interpret: bool | None = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two-dispatch (covariance + correlation) pallas baseline with the
    same routing policy — exists for the fused-vs-unfused benchmark
    pair and as a second kernel-path parity anchor in tests."""
    m, n, p = Xs.shape
    interp = (not on_tpu()) if interpret is None else interpret
    if is_ragged_samples(n, p):
        return rank_update_ref(Xs, ys, weights)
    bp, bn = resolve_rank_blocks(n, p, block)
    return rank_update_unfused_pallas(Xs, ys, weights, bp=bp, bn=bn,
                                      interpret=interp)
