"""Dispatcher for the fused rank-n sufficient-statistics update.

Same convention as `kernels/ista_step/ops.py` and
`kernels/logistic_grad/ops.py`: pallas on MXU-friendly shapes
(interpret mode off-TPU), the jnp oracle on ragged shapes — and the
oracle is bitwise the historical `sufficient_stats` einsum pair, so the
CPU default path perturbs nothing downstream.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp

from repro.kernels.common import (
    aligned_fit_block, degrades_to_slivers, is_ragged_samples, on_tpu,
    record_route, validate_block,
)
from repro.kernels.rank_update.kernel import (
    rank_update_pallas, rank_update_unfused_pallas,
)
from repro.kernels.rank_update.ref import rank_update_ref


# per-dispatch VMEM budget for one grid step, same 8 MB envelope as the
# logistic kernel (half the ~16 MB core, slack for double-buffering)
RANK_VMEM_BUDGET = 8 * 1024 * 1024


def rank_vmem_bytes(bp: int, bn: int) -> int:
    """Estimated VMEM footprint of one fused-kernel grid step: the two
    (bn, bp) X slabs (xi, xj) double-buffered at their true f32 size
    with the lane axis padded to full 128-lane register tiles, the
    (bp, bp) Sigma output tile, and the trailing-singleton y/c buffers
    at their PADDED 512 B/row width (a (r, 1) f32 buffer occupies full
    (8, 128) register tiles on TPU). The byte model is the checked
    contract shared with tools/repro_lint's static tiling pass — an
    explicit `block=` the model rejects routes to the bitwise oracle
    instead of compiling a Mosaic OOM."""
    lanes = ((bp + 127) // 128) * 128
    return 16 * bn * lanes + 4 * bp * lanes + 512 * (bn + bp)


def resolve_rank_blocks(n: int, p: int, block) -> Tuple[int, int]:
    """Normalize a block policy to concrete (bp, bn) tile sizes.
    `block` is one int (applied to both axes) or an explicit (bp, bn)
    pair — note the order, feature axis first — e.g. an autotuned
    winner from `repro.kernels.autotune.autotune_rank_block`; anything
    else raises instead of being silently coerced (the logistic
    dispatcher's old `block[0]` bug, audited here too). Each entry is
    clipped to the largest 8-aligned divisor of its dimension, the same
    notion of "legal" the routing predicate judges by."""
    bp, bn = validate_block(block, 2, "(bp, bn)")
    return aligned_fit_block(p, bp), aligned_fit_block(n, bn)


def _rank_route_reason(n: int, p: int, block=128) -> Optional[str]:
    """Routing verdict plus its telemetry label: None on the kernel
    path, else `ragged` / `sliver` / `vmem_budget` (same clause set as
    ever; the order only picks the label when several apply)."""
    bp_req, bn_req = validate_block(block, 2, "(bp, bn)")
    bp, bn = resolve_rank_blocks(n, p, block)
    if is_ragged_samples(n, p):
        return "ragged"
    if degrades_to_slivers(n, bn_req) or degrades_to_slivers(p, bp_req):
        return "sliver"
    if rank_vmem_bytes(bp, bn) > RANK_VMEM_BUDGET:
        return "vmem_budget"
    return None


def rank_routes_to_oracle(n: int, p: int, block=128) -> bool:
    """Routing predicate shared with the engine's rank block policy:
    ragged shapes, shapes whose requested tiles degrade to sliver grids
    (e.g. n = 1016 against a 128 request), and resolved tilings whose
    grid step busts `RANK_VMEM_BUDGET` (an explicit block= large enough
    that the X slabs or the Sigma tile outgrow VMEM) go to the jnp
    oracle."""
    return _rank_route_reason(n, p, block) is not None


def rank_update(Xs, ys, weights=None, *, block=128,
                interpret: bool | None = None,
                use_kernel: bool | None = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-task statistics (n^-1 X'WX, n^-1 X'Wy) for a sample chunk.

    Xs (m, n, p), ys (m, n), optional weights (m, n) ->
    (Sigmas (m, p, p), cs (m, p)). Routes to the fused pallas kernel on
    MXU-friendly shapes when `use_kernel` (default: only on TPU — the
    XLA einsum oracle is the fast CPU path); ragged shapes always take
    the oracle. `block` is an int or an explicit (bp, bn) pair.
    """
    m, n, p = Xs.shape
    # resolve (and so validate) blocks BEFORE the oracle short-circuit:
    # a malformed block must raise on every path, not only on TPU
    bp, bn = resolve_rank_blocks(n, p, block)
    if use_kernel is None:
        use_kernel = on_tpu()
    interp = (not on_tpu()) if interpret is None else interpret
    reason = _rank_route_reason(n, p, block)
    if not use_kernel or reason is not None:
        record_route("rank_update", reason or "backend", blocks=(bp, bn))
        return rank_update_ref(Xs, ys, weights)
    record_route("rank_update", None, blocks=(bp, bn))
    return rank_update_pallas(Xs, ys, weights, bp=bp, bn=bn,
                              interpret=interp)


def rank_update_unfused(Xs, ys, weights=None, *, block=128,
                        interpret: bool | None = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Two-dispatch (covariance + correlation) pallas baseline with the
    same routing policy — exists for the fused-vs-unfused benchmark
    pair and as a second kernel-path parity anchor in tests."""
    m, n, p = Xs.shape
    bp, bn = resolve_rank_blocks(n, p, block)
    interp = (not on_tpu()) if interpret is None else interpret
    reason = _rank_route_reason(n, p, block)
    record_route("rank_update_unfused", reason, blocks=(bp, bn))
    if reason is not None:
        return rank_update_ref(Xs, ys, weights)
    return rank_update_unfused_pallas(Xs, ys, weights, bp=bp, bn=bn,
                                      interpret=interp)
