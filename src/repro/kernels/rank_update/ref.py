"""Pure-jnp oracle for the fused rank-n sufficient-statistics update.

The reduction every DSML path starts from — and the streaming layer's
always-on hot loop (`stream/state.ingest`):

    Sigma = n^-1 X' W X,    c = n^-1 X' W y     (W optional, diagonal)

for all m tasks. This oracle IS the historical `core/engine.
sufficient_stats` einsum pair (bitwise — the dispatcher's CPU path must
not perturb any downstream solve) and the reference the Pallas kernel
is tested against.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


@partial(jax.jit, static_argnames=())
def rank_update_ref(Xs: jnp.ndarray, ys: jnp.ndarray,
                    weights: jnp.ndarray | None = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Xs (m, n, p), ys (m, n), weights optional (m, n) ->
    Sigmas (m, p, p), cs (m, p), both normalized by n (NOT sum(w) —
    the caller owns the weighted-count convention)."""
    n = Xs.shape[1]
    Xl = Xs if weights is None else Xs * weights[..., None]
    Sigmas = jnp.einsum("tni,tnj->tij", Xl, Xs) / n
    cs = jnp.einsum("tni,tn->ti", Xl, ys) / n
    return Sigmas, cs
