"""Oracle for the Pallas flash-attention kernel: the pure-jnp blockwise
implementation in repro.models.attention_core (itself validated against
dense softmax attention in tests/test_attention_core.py)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention_core import flash_attention as _flash_jnp


def flash_attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """q: (B, S, N, H); k/v: (B, T, K, H) -> (B, S, N, H)."""
    S, T = q.shape[1], k.shape[1]
    return _flash_jnp(q, k, v,
                      q_pos=jnp.arange(S), k_pos=jnp.arange(T),
                      causal=causal, window=window)
