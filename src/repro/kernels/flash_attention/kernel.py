"""Pallas TPU kernel: causal flash attention forward (serving path).

Grid: (batch*heads, q_tiles, kv_tiles) — kv is the innermost (sequential)
dimension; the online-softmax state (m, l) and the f32 output accumulator
live in VMEM scratch and persist across kv steps. Each step does one
(BQ, H) x (H, BK) score matmul and one (BQ, BK) x (BK, H) value matmul on
the MXU; masking and the rescale are VPU ops. Causality additionally
skips whole kv tiles above the diagonal with @pl.when (the classic
triangle-skipping schedule).

Layout: q (BH, S, H), k/v (BH, T, H) — heads pre-broadcast for GQA by the
ops.py wrapper (kv head replication happens at gather cost in VMEM, not
HBM, on real TPU thanks to the BlockSpec index_map reuse).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = kj * bk

    # tile-level causal skip: no key in this tile can be visible
    run = (k_start <= q_start + bq - 1) if causal else (kj >= 0)

    @pl.when(run)
    def _step():
        q = q_ref[0]                                  # (bq, h)
        k = k_ref[0]                                  # (bk, h)
        v = v_ref[0]
        h = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) / jnp.sqrt(h).astype(jnp.float32)

        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        # mask multiply guards fully-masked tiles (exp(-inf - -inf) == 1)
        p = jnp.exp(s - m_new) * mask
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv

    @pl.when(kj == nk - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        out_ref[0] = (acc_ref[...] / l).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           bq: int = 256, bk: int = 256,
                           interpret: bool = False):
    """q: (BH, S, H), k/v: (BH, T, H) -> (BH, S, H)."""
    BH, S, H = q.shape
    T = k.shape[1]
    bq = min(bq, S)
    bk = min(bk, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    nq, nk = S // bq, T // bk

    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal,
                          window=window),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, H), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, H), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, H), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, H), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, H), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # m
            pltpu.VMEM((bq, 1), jnp.float32),   # l
            pltpu.VMEM((bq, H), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(q, k, v)
