"""Jit'd wrapper: standard (B, S, N, H) layout -> Pallas flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       bq: int = 256, bk: int = 256,
                       interpret: bool | None = None):
    """q: (B, S, N, H); k/v: (B, T, K, H) with N % K == 0 -> (B, S, N, H)."""
    B, S, N, H = q.shape
    T, K = k.shape[1], k.shape[2]
    G = N // K
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret

    qf = q.transpose(0, 2, 1, 3).reshape(B * N, S, H)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, T, H)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, T, H)

    bq_ = bq
    while S % bq_:
        bq_ //= 2
    bk_ = bk
    while T % bk_:
        bk_ //= 2

    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 bq=bq_, bk=bk_, interpret=interp)
    return out.reshape(B, N, S, H).transpose(0, 2, 1, 3)
