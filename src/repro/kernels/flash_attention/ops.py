"""Dispatcher for the Pallas flash-attention kernel (serving path).

Same convention as the other `kernels/*/ops.py` dispatchers: the pallas
kernel on tile-able shapes (interpret mode off-TPU so the same
BlockSpecs execute everywhere), the pure-jnp blockwise oracle
(`ref.flash_attention_ref`, itself validated against dense softmax
attention) on ragged or sliver-degraded sequence shapes — the seed-era
wrapper had NO fallback and halved its tile requests unvalidated, so an
odd sequence length quietly bottomed out at single-row tiles. `bq`/`bk`
stay as the public tile knobs (call sites pin them); they are validated
through `common.validate_block` and clipped with `aligned_fit_block`,
the same notion of "legal tile" every other dispatcher judges by.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp

from repro.kernels.common import (
    aligned_fit_block, degrades_to_slivers, on_tpu, record_route,
    validate_block,
)
from repro.kernels.flash_attention.kernel import flash_attention_pallas
from repro.kernels.flash_attention.ref import flash_attention_ref


def resolve_flash_blocks(S: int, T: int, block) -> Tuple[int, int]:
    """Normalize a (bq, bk) request to concrete query/key tiles: each
    entry clipped to the largest 8-ALIGNED divisor of its sequence axis
    (the seed-era halving loop could land on 1-row tiles for odd
    lengths instead of falling back)."""
    bq, bk = validate_block(block, 2, "(bq, bk)")
    return aligned_fit_block(S, bq), aligned_fit_block(T, bk)


def flash_routes_to_oracle(S: int, T: int, block=(256, 256)) -> bool:
    """Routing predicate: ragged sequence axes (S or T not 8-aligned)
    and tiles that degrade to slivers against the request go to the jnp
    oracle. Validates `block` on every path."""
    bq, bk = validate_block(block, 2, "(bq, bk)")
    return (bool(S % 8 or T % 8) or degrades_to_slivers(S, bq)
            or degrades_to_slivers(T, bk))


def flash_attention_op(q, k, v, *, causal: bool = True, window: int = 0,
                       bq: int = 256, bk: int = 256,
                       interpret: bool | None = None):
    """q: (B, S, N, H); k/v: (B, T, K, H) with N % K == 0 -> (B, S, N, H)."""
    B, S, N, H = q.shape
    T, K = k.shape[1], k.shape[2]
    G = N // K
    bq_, bk_ = resolve_flash_blocks(S, T, (bq, bk))
    interp = (not on_tpu()) if interpret is None else interpret
    if flash_routes_to_oracle(S, T, (bq, bk)):
        record_route("flash_attention",
                     "ragged" if (S % 8 or T % 8) else "sliver",
                     blocks=(bq_, bk_))
        return flash_attention_ref(q, k, v, causal=causal, window=window)
    record_route("flash_attention", None, blocks=(bq_, bk_))

    qf = q.transpose(0, 2, 1, 3).reshape(B * N, S, H)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, T, H)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1).reshape(B * N, T, H)

    out = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                 bq=bq_, bk=bk_, interpret=interp)
    return out.reshape(B, N, S, H).transpose(0, 2, 1, 3)
