"""Block-size autotuning for the batched Pallas solver kernels.

Three kernel families are shape-polymorphic over their problem sizes
and their best tilings depend on the backend and dtype:

  * `fista_step` — the fused ISTA/FISTA solver step, swept over
    (bp, br, bk) for a (m, p, r) solve;
  * `logistic_grad` — the fused all-tasks logistic gradient, swept over
    (bn, bp) sample/feature tiles for a (m, n, p) batch (large-p shapes
    sweep real feature tilings under the per-tile VMEM budget);
  * `rank_update` — the fused rank-n sufficient-statistics update,
    swept over (bp, bn) for a (m, n, p) chunk.

Each `autotune_*` entry point times the candidate tilings for a given
problem key once, then serves the winner from an in-process cache
backed by a JSON file under the repo cache dir (`.cache/autotune.json`,
override with $REPRO_CACHE_DIR), so a process restart never re-times a
known key. Cache keys are NAMESPACED PER KERNEL
(`"<kernel>/<backend>_<dims>_<dtype>"`); legacy un-namespaced entries
(pre-namespace files were written only by the fista sweep) are migrated
to `fista_step/...` on load.

The engine (`core/engine.py`) uses these as its default block policies:
`solve_lasso_batched(block=None)` / `solve_logistic_lasso_batched
(block=None)` / `sufficient_stats(block=None)` on the kernel path look
the winner up here; an explicit `block=` always wins and never touches
the cache.
"""
from __future__ import annotations

import json
import os
import re
import time
from pathlib import Path
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.kernels.ista_step.kernel import fista_step_batched_pallas
from repro.kernels.ista_step.ops import resolve_blocks
from repro.kernels.logistic_grad.kernel import logistic_grad_pallas
from repro.kernels.logistic_grad.ops import (
    LOGISTIC_VMEM_BUDGET, kernel_vmem_bytes, resolve_logistic_blocks,
    routes_to_oracle,
)
from repro.kernels.rank_update.kernel import rank_update_pallas

_REPO_ROOT = Path(__file__).resolve().parents[3]
CACHE_FILE = "autotune.json"

# block candidates per grid axis; intersected with the divisors of the
# actual dimension, so every candidate is a legal BlockSpec tiling
BLOCK_CANDIDATES = (32, 64, 128, 256)

_memory_cache: Dict[str, tuple] = {}


def cache_path() -> Path:
    return Path(os.environ.get("REPRO_CACHE_DIR",
                               _REPO_ROOT / ".cache")) / CACHE_FILE


def cache_key(kernel: str, backend: str, dims: Dict[str, int],
              dtype) -> str:
    """Per-kernel-namespaced key: "<kernel>/<backend>_m4_p128_..._f32".
    Entries for different kernels can never collide even when their
    dimension tuples coincide (e.g. a (m, n, p) logistic sweep vs a
    (m, p, r) solver sweep with equal numbers)."""
    dim_s = "_".join(f"{k}{v}" for k, v in dims.items())
    return f"{kernel}/{backend}_{dim_s}_{jnp.dtype(dtype).name}"


def clear_memory_cache() -> None:
    _memory_cache.clear()


def _migrate(entries: dict) -> Tuple[dict, bool]:
    """Namespace legacy keys. Files written before the per-kernel
    namespace held only fista sweeps under bare "<backend>_..." keys;
    prefix them so old caches keep serving (and never shadow or absorb
    the new kernels' entries). Pre-feature-tiling `logistic_grad/`
    entries were a bare int bn with an implicit full-lane bp = p: widen
    them through the budgeted resolver ((n, p) read back off the key),
    NOT to a literal [bn, p] — a legacy winner like bn = 256 at
    p = 4096 pairs with a full-lane slab that busts the new VMEM
    budget, and a migrated entry the dispatcher silently routes to the
    oracle would permanently lose that shape its kernel path."""
    migrated, changed = {}, False
    for k, v in entries.items():
        rewritten = False
        if "/" not in k:
            k, changed, rewritten = f"fista_step/{k}", True, True
        if k.startswith("logistic_grad/") and not isinstance(v, list):
            dims = re.search(r"_n(\d+)_p(\d+)_", k)
            if dims:
                n_k, p_k = int(dims.group(1)), int(dims.group(2))
                v = list(resolve_logistic_blocks(n_k, p_k, int(v)))
                changed, rewritten = True, True
        if rewritten:
            obs.inc("autotune.cache", kernel=k.split("/", 1)[0],
                    event="migrated")
        migrated[k] = v
    return migrated, changed


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            entries = json.load(f)
    except (OSError, ValueError):
        return {}
    entries, changed = _migrate(entries)
    if changed:
        _save_disk(entries)      # rewrite once; best-effort if read-only
    return entries


def _save_disk(entries: dict) -> None:
    path = cache_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(entries, indent=2, sort_keys=True))
        os.replace(tmp, path)
    except OSError:
        pass  # read-only checkout: the in-process cache still serves


def _divisor_candidates(size: int) -> List[int]:
    return [b for b in BLOCK_CANDIDATES if b <= size and size % b == 0] \
        or [size]


def block_candidates(p: int, r: int) -> List[Tuple[int, int, int]]:
    """Legal (bp, br, bk) tilings to sweep for a (p, r) solve. bk is
    tied to bp (the contraction tile streams the same Sigma rows the
    output tile covers), so the sweep is |bp| x |br| candidates."""
    bps = _divisor_candidates(p)
    brs = [1] if r == 1 else _divisor_candidates(r)
    return [(bp, br, bp) for bp in bps for br in brs]


def logistic_candidates(n: int, p: int) -> List[Tuple[int, int]]:
    """Legal (bn, bp) tilings to sweep for a (m, n, p) logistic-gradient
    batch, filtered to the kernel's per-tile VMEM budget. The feature
    axis adds the large lane tiles (512..4096) and the full-lane bp = p
    layout on top of the shared candidate grid, so small p sweeps the
    historical resident slab and large p sweeps real feature tilings."""
    bps = _divisor_candidates(p)
    bps += [b for b in (512, 1024, 2048, 4096)
            if b < p and p % b == 0 and b not in bps]
    if p not in bps:
        bps.append(p)
    pairs = [(bn, bp) for bn in _divisor_candidates(n) for bp in bps
             if kernel_vmem_bytes(p, bn, bp) <= LOGISTIC_VMEM_BUDGET]
    return pairs or [resolve_logistic_blocks(n, p)]


def rank_candidates(n: int, p: int) -> List[Tuple[int, int]]:
    """Legal (bp, bn) tilings to sweep for a (m, n, p) rank-n update."""
    return [(bp, bn) for bp in _divisor_candidates(p)
            for bn in _divisor_candidates(n)]


def _time_candidate(fn, reps: int) -> float:
    """Best-of-`reps` wall time of `fn()` in microseconds (warm-up call
    synced first so compile time never counts). Module-level so tests
    can count sweep invocations."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _autotune(kernel: str, dims: Dict[str, int], default, candidates,
              make_sweep: Callable, *, dtype, backend: str | None,
              interpret: bool | None, reps: int, use_disk: bool):
    """Shared cache-then-sweep policy behind every `autotune_*` entry
    point. `make_sweep(interp)` builds the synthetic sweep inputs and
    returns a `candidate -> timing thunk` factory — called only when a
    sweep actually runs, so warm-cache hits on the engine hot path
    never pay a problem-sized allocation. The winner is written back to
    both caches.

    Multi-controller guard: a winner becomes a STATIC compile
    parameter, and a timing sweep is not deterministic across hosts —
    divergent winners would compile divergent executables for one SPMD
    program. With more than one jax process every host returns the same
    deterministic default instead of sweeping.
    """
    if jax.process_count() > 1:
        obs.inc("autotune.cache", kernel=kernel, event="default_multiprocess")
        return default
    backend = jax.default_backend() if backend is None else backend
    key = cache_key(kernel, backend, dims, dtype)
    if key in _memory_cache:
        obs.inc("autotune.cache", kernel=kernel, event="hit_memory")
        return _memory_cache[key]
    disk = _load_disk() if use_disk else {}
    if key in disk:
        v = disk[key]
        blk = tuple(int(b) for b in v) if isinstance(v, list) else int(v)
        _memory_cache[key] = blk
        obs.inc("autotune.cache", kernel=kernel, event="hit_disk")
        return blk

    # A warm cache is servable anywhere (the lookups above), but the
    # SWEEP must not run while a caller's jit trace is active: the
    # candidate calls would return tracers, `block_until_ready` would
    # be a no-op, and trace-time noise would be cached as the permanent
    # winner. Fall back to the deterministic default — uncached, so a
    # later eager call (`warmup_cache`) can still tune this key. If the
    # installed jax no longer exposes trace_state_clean, fail CLOSED
    # (assume a trace may be active): a never-swept cache serves the
    # safe default, a trace-noise-poisoned cache is permanent.
    if not getattr(jax.core, "trace_state_clean", lambda: False)():
        obs.inc("autotune.cache", kernel=kernel, event="deferred_trace")
        return default

    obs.inc("autotune.cache", kernel=kernel, event="miss_sweep")
    interp = (backend != "tpu") if interpret is None else interpret
    fn_for = make_sweep(interp)
    best_us, best = float("inf"), default
    with obs.span("autotune.sweep", kernel=kernel):
        for cand in candidates:
            us = _time_candidate(fn_for(cand), reps)
            obs.observe("autotune.candidate_us", us, kernel=kernel,
                        candidate="x".join(str(b) for b in cand)
                        if isinstance(cand, tuple) else str(cand))
            if us < best_us:
                best_us, best = us, cand
    _memory_cache[key] = best
    if use_disk:
        disk[key] = list(best) if isinstance(best, tuple) else best
        _save_disk(disk)
    return best


def warmup_cache(m: int, p: int, n: int | None = None, *,
                 dtype=jnp.float32, reps: int = 2) -> None:
    """Eagerly tune the solve shapes a DSML workload of m tasks in p
    dims hits — the r=1 lasso batch and the r=p multi-RHS debias solve,
    plus (when the chunk size `n` is known) the rank-n ingest and
    logistic-gradient shapes — so later JITTED engine calls find a warm
    cache. Large-p logistic shapes (past the old full-lane cliff) warm
    like any other now that the kernel feature-tiles its slabs.

    This is the intended production entry point: every in-repo solver
    is jitted, and the sweep refuses to run under an active trace
    (see `_autotune`), so without an eager warm-up the engine keeps the
    deterministic 128 default. Call once at startup
    (`StreamingDsmlService` does, on TPU). No-op off-TPU, where the
    engine's default path is the jnp oracle and a sweep would time the
    slow interpreter for nothing.
    """
    if jax.default_backend() != "tpu":
        return
    autotune_block(m, p, 1, dtype=dtype, reps=reps)
    autotune_block(m, p, p, dtype=dtype, reps=reps)
    if n is not None:
        autotune_logistic_block(m, n, p, dtype=dtype, reps=reps)
        autotune_rank_block(m, n, p, dtype=dtype, reps=reps)


def autotune_block(m: int, p: int, r: int, *, dtype=jnp.float32,
                   backend: str | None = None,
                   interpret: bool | None = None,
                   candidates: List[Tuple[int, int, int]] | None = None,
                   reps: int = 2, use_disk: bool = True
                   ) -> Tuple[int, int, int]:
    """Winning (bp, br, bk) tiling for a batched FISTA solve step of
    this (m, p, r) shape (kernel namespace `fista_step`)."""
    def make_sweep(interp):
        k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
        Sigmas = jax.random.normal(k0, (m, p, p), dtype)
        zs = jax.random.normal(k1, (m, p, r), dtype)
        cs = jax.random.normal(k2, (m, p, r), dtype)
        etas = jnp.full((m,), 0.01, dtype)
        return lambda cand: lambda: fista_step_batched_pallas(
            Sigmas, zs, zs, cs, etas, 0.1, 0.5, bp=cand[0], br=cand[1],
            bk=cand[2], interpret=interp)

    return _autotune(
        "fista_step", {"m": m, "p": p, "r": r},
        resolve_blocks(p, r, 128),
        block_candidates(p, r) if candidates is None else candidates,
        make_sweep, dtype=dtype, backend=backend, interpret=interpret,
        reps=reps, use_disk=use_disk)


def autotune_logistic_block(m: int, n: int, p: int, *, dtype=jnp.float32,
                            backend: str | None = None,
                            interpret: bool | None = None,
                            candidates: List[Tuple[int, int]] | None = None,
                            reps: int = 2, use_disk: bool = True
                            ) -> Tuple[int, int]:
    """Winning (bn, bp) tiling for a (m, n, p) fused logistic-gradient
    batch (kernel namespace `logistic_grad`). Feature-tiled large-p
    shapes sweep too — the old full-lane p cliff routed them to the
    oracle before a sweep could even run. Shapes the dispatcher will
    not serve (ragged, sliver, over-budget) return the budgeted
    default untimed so the cache is never polluted with them."""
    default = resolve_logistic_blocks(n, p)
    if routes_to_oracle(n, p):
        return default

    def make_sweep(interp):
        k0, k1 = jax.random.split(jax.random.PRNGKey(0))
        Xs = jax.random.normal(k0, (m, n, p), dtype)
        ys = jnp.sign(jax.random.normal(k1, (m, n), dtype))
        B = jnp.zeros((m, p), dtype)
        return lambda cand: lambda: logistic_grad_pallas(
            Xs, ys, B, bn=cand[0], bp=cand[1], interpret=interp)

    return _autotune(
        "logistic_grad", {"m": m, "n": n, "p": p}, default,
        logistic_candidates(n, p) if candidates is None else candidates,
        make_sweep, dtype=dtype, backend=backend, interpret=interpret,
        reps=reps, use_disk=use_disk)


def autotune_rank_block(m: int, n: int, p: int, *, dtype=jnp.float32,
                        backend: str | None = None,
                        interpret: bool | None = None,
                        candidates: List[Tuple[int, int]] | None = None,
                        reps: int = 2, use_disk: bool = True
                        ) -> Tuple[int, int]:
    """Winning (bp, bn) tiling for a (m, n, p) fused rank-n statistics
    update (kernel namespace `rank_update`). As in the logistic sweep,
    shapes the dispatcher routes to the oracle (ragged, sliver tiles)
    return the default untimed so the cache is never polluted with
    unservable keys."""
    from repro.kernels.rank_update.ops import (
        rank_routes_to_oracle, resolve_rank_blocks,
    )
    if rank_routes_to_oracle(n, p):
        return resolve_rank_blocks(n, p, 128)

    def make_sweep(interp):
        k0, k1 = jax.random.split(jax.random.PRNGKey(0))
        Xs = jax.random.normal(k0, (m, n, p), dtype)
        ys = jax.random.normal(k1, (m, n), dtype)
        # tune the unweighted specialization — the always-on ingest case
        return lambda cand: lambda: rank_update_pallas(
            Xs, ys, bp=cand[0], bn=cand[1], interpret=interp)

    return _autotune(
        "rank_update", {"m": m, "n": n, "p": p},
        resolve_rank_blocks(n, p, 128),
        rank_candidates(n, p) if candidates is None else candidates,
        make_sweep, dtype=dtype, backend=backend, interpret=interpret,
        reps=reps, use_disk=use_disk)
