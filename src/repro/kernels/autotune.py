"""Block-size autotuning for the batched ISTA/FISTA Pallas kernels.

The fused solver step is shape-polymorphic over (m, p, r) and its best
(bp, br, bk) tiling depends on the backend and dtype: the 128x128 MXU
default is right for large square solves, but small-m/multi-RHS debias
solves and skinny r=1 lasso batches favour other tiles. `autotune_block`
times the candidate tilings for a given problem key once, then serves
the winner from an in-process cache backed by a JSON file under the repo
cache dir (`.cache/autotune.json`, override with $REPRO_CACHE_DIR), so a
process restart never re-times a known key.

The engine (`core/engine.py`) uses this as its default block policy:
`solve_lasso_batched(block=None)` on the kernel path looks the winner up
here; an explicit `block=` always wins and never touches the cache.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ista_step.kernel import fista_step_batched_pallas
from repro.kernels.ista_step.ops import resolve_blocks

_REPO_ROOT = Path(__file__).resolve().parents[3]
CACHE_FILE = "autotune.json"

# block candidates per grid axis; intersected with the divisors of the
# actual dimension, so every candidate is a legal BlockSpec tiling
BLOCK_CANDIDATES = (32, 64, 128, 256)

_memory_cache: Dict[str, Tuple[int, int, int]] = {}


def cache_path() -> Path:
    return Path(os.environ.get("REPRO_CACHE_DIR",
                               _REPO_ROOT / ".cache")) / CACHE_FILE


def cache_key(backend: str, m: int, p: int, r: int, dtype) -> str:
    return f"{backend}_m{m}_p{p}_r{r}_{jnp.dtype(dtype).name}"


def clear_memory_cache() -> None:
    _memory_cache.clear()


def _load_disk() -> dict:
    try:
        with open(cache_path()) as f:
            return json.load(f)
    except (OSError, ValueError):
        return {}


def _save_disk(entries: dict) -> None:
    path = cache_path()
    try:
        path.parent.mkdir(parents=True, exist_ok=True)
        tmp = path.with_suffix(".tmp")
        tmp.write_text(json.dumps(entries, indent=2, sort_keys=True))
        os.replace(tmp, path)
    except OSError:
        pass  # read-only checkout: the in-process cache still serves


def block_candidates(p: int, r: int) -> List[Tuple[int, int, int]]:
    """Legal (bp, br, bk) tilings to sweep for a (p, r) solve. bk is
    tied to bp (the contraction tile streams the same Sigma rows the
    output tile covers), so the sweep is |bp| x |br| candidates."""
    bps = [b for b in BLOCK_CANDIDATES if b <= p and p % b == 0] or [p]
    if r == 1:
        brs = [1]
    else:
        brs = [b for b in BLOCK_CANDIDATES if b <= r and r % b == 0] or [r]
    return [(bp, br, bp) for bp in bps for br in brs]


def _time_candidate(fn, reps: int) -> float:
    """Best-of-`reps` wall time of `fn()` in microseconds (warm-up call
    synced first so compile time never counts). Module-level so tests
    can count sweep invocations."""
    jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def warmup_cache(m: int, p: int, *, dtype=jnp.float32,
                 reps: int = 2) -> None:
    """Eagerly tune the two solve shapes a DSML workload of m tasks in
    p dims hits — the r=1 lasso batch and the r=p multi-RHS debias
    solve — so later JITTED engine calls find a warm cache.

    This is the intended production entry point: every in-repo solver
    is jitted, and the sweep refuses to run under an active trace
    (see `autotune_block`), so without an eager warm-up the engine
    keeps the deterministic 128 default. Call once at startup
    (`StreamingDsmlService` does, on TPU). No-op off-TPU, where the
    engine's default path is the jnp oracle and a sweep would time the
    slow interpreter for nothing.
    """
    if jax.default_backend() != "tpu":
        return
    autotune_block(m, p, 1, dtype=dtype, reps=reps)
    autotune_block(m, p, p, dtype=dtype, reps=reps)


def autotune_block(m: int, p: int, r: int, *, dtype=jnp.float32,
                   backend: str | None = None,
                   interpret: bool | None = None,
                   candidates: List[Tuple[int, int, int]] | None = None,
                   reps: int = 2, use_disk: bool = True
                   ) -> Tuple[int, int, int]:
    """Winning (bp, br, bk) tiling for a batched solve of this shape.

    Cache policy: in-process dict first, then the on-disk JSON, then a
    timing sweep of `candidates` (default `block_candidates(p, r)`) on
    synthetic data whose winner is written back to both caches.

    Multi-controller guard: the winner becomes a STATIC compile
    parameter, and a timing sweep is not deterministic across hosts —
    divergent winners would compile divergent executables for one SPMD
    program. With more than one jax process every host returns the
    same deterministic default instead of sweeping.
    """
    if jax.process_count() > 1:
        return resolve_blocks(p, r, 128)    # historical default, no sweep
    backend = jax.default_backend() if backend is None else backend
    key = cache_key(backend, m, p, r, dtype)
    if key in _memory_cache:
        return _memory_cache[key]
    disk = _load_disk() if use_disk else {}
    if key in disk:
        blk = tuple(int(b) for b in disk[key])
        _memory_cache[key] = blk
        return blk

    # A warm cache is servable anywhere (the lookups above), but the
    # SWEEP must not run while a caller's jit trace is active: the
    # candidate calls would return tracers, `block_until_ready` would
    # be a no-op, and trace-time noise would be cached as the permanent
    # winner. Fall back to the deterministic default — uncached, so a
    # later eager call (`warmup_cache`) can still tune this key. If the
    # installed jax no longer exposes trace_state_clean, fail CLOSED
    # (assume a trace may be active): a never-swept cache serves the
    # safe default, a trace-noise-poisoned cache is permanent.
    if not getattr(jax.core, "trace_state_clean", lambda: False)():
        return resolve_blocks(p, r, 128)

    interp = (backend != "tpu") if interpret is None else interpret
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(0), 3)
    Sigmas = jax.random.normal(k0, (m, p, p), dtype)
    zs = jax.random.normal(k1, (m, p, r), dtype)
    cs = jax.random.normal(k2, (m, p, r), dtype)
    etas = jnp.full((m,), 0.01, dtype)

    best_us, best = float("inf"), None
    for bp, br, bk in (block_candidates(p, r) if candidates is None
                       else candidates):
        fn = lambda: fista_step_batched_pallas(
            Sigmas, zs, zs, cs, etas, 0.1, 0.5, bp=bp, br=br, bk=bk,
            interpret=interp)
        us = _time_candidate(fn, reps)
        if us < best_us:
            best_us, best = us, (bp, br, bk)

    _memory_cache[key] = best
    if use_disk:
        disk[key] = list(best)
        _save_disk(disk)
    return best
