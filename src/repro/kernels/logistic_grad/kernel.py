"""Pallas TPU kernel: fused all-tasks logistic gradient.

One dispatch computes, for every task t, the full gradient of the
logistic loss at the current iterate:

    z = X b,   r = y * sigmoid(-y z),   g = -X' r / n.

Tiling (DESIGN.md §11-§12): X slabs are (bn, bp) — the sample axis
tiled in `bn`-row strips AND the feature axis tiled in `bp`-lane
strips, so no shape keeps the full feature dimension resident and the
kernel serves the paper's own p >> n regime past the old full-lane
VMEM cliff. Two layouts share one dispatch convention:

  * RESIDENT (bp == p, the small-p fast path): grid (m, nj). Each
    (t, j) step loads one (bn, p) slab and fires the forward matvec,
    the sigmoid residual, and the back-projection on the same resident
    tile — X streams through VMEM exactly once. This is bitwise the
    pre-tiling kernel, so existing shapes see zero perf or numerics
    change.
  * FEATURE-TILED (bp < p): grid (m, nj, 2*pi). For each sample tile j
    the inner axis makes TWO passes over the pi feature tiles: a
    forward sweep accumulating the partial matvec X_j[:, i] @ b_i into
    a (bn, 1) f32 VMEM carry, then — once the carry holds the complete
    z_j and the sigmoid residual can fire — a backward sweep in
    REVERSE feature order (i = 2*pi-1-k), so the turnaround tile
    (i = pi-1) is still resident in VMEM and is never refetched. Each
    backward visit adds X_j[:, i]' r_j into row i of a (pi, bp, 1) f32
    gradient accumulator that persists across the j sweep; the
    epilogue scales by -1/n (a compile-time constant) on the last
    sample tile. z and r never exist in HBM.

The dispatcher (`ops.py`) picks (bn, bp) via the budgeted block policy
— full-lane whenever the slab fits the per-tile VMEM budget, tiled
past it — and routes ragged / sliver / over-budget shapes to the jnp
oracle.

`logistic_z_pallas` / `logistic_backproject_pallas` are the UNFUSED
halves (forward matvec only / back-projection of a precomputed
residual), feature-tiled the same way. They exist as the two-dispatch
baseline the fused kernel is benchmarked against
(benchmarks/kernels_bench.py) — same tiles, same arithmetic, one extra
HBM round trip for the residual.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _resident_grad_kernel(x_ref, y_ref, b_ref, out_ref, acc_ref, *,
                          nj: int, inv_n: float):
    """bp == p: full feature axis in lanes, one pass per sample tile."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                        # (bn, p)
    z = jnp.dot(x, b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)     # (bn, 1)
    y = y_ref[0].astype(jnp.float32)                    # (bn, 1)
    r = y * jax.nn.sigmoid(-y * z)
    acc_ref[...] += jnp.dot(x.T, r,
                            preferred_element_type=jnp.float32)  # (p, 1)

    @pl.when(j == nj - 1)
    def _epilogue():
        out_ref[0] = (-inv_n * acc_ref[...]).astype(out_ref.dtype)


def _tiled_grad_kernel(x_ref, y_ref, b_ref, out_ref, z_acc, g_acc, *,
                       pi: int, nj: int, inv_n: float):
    """bp < p: forward feature sweep fills the z carry, the reversed
    backward sweep back-projects off the same (turnaround-resident)
    tiles into the per-feature-tile gradient accumulator."""
    j = pl.program_id(1)
    k = pl.program_id(2)

    @pl.when(jnp.logical_and(j == 0, k == 0))
    def _init_g():
        g_acc[...] = jnp.zeros_like(g_acc)

    @pl.when(k == 0)
    def _init_z():
        z_acc[...] = jnp.zeros_like(z_acc)

    x = x_ref[0]                                        # (bn, bp)

    @pl.when(k < pi)
    def _forward():
        z_acc[...] += jnp.dot(x, b_ref[0].astype(jnp.float32),
                              preferred_element_type=jnp.float32)

    @pl.when(k >= pi)
    def _backward():
        i = 2 * pi - 1 - k                              # reverse sweep
        y = y_ref[0].astype(jnp.float32)                # (bn, 1)
        r = y * jax.nn.sigmoid(-y * z_acc[...])
        g_acc[i] += jnp.dot(x.T, r,
                            preferred_element_type=jnp.float32)  # (bp, 1)

        @pl.when(j == nj - 1)
        def _epilogue():
            out_ref[0] = (-inv_n * g_acc[i]).astype(out_ref.dtype)


def _logistic_z_kernel(x_ref, b_ref, z_ref, z_acc, *, pi: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        z_acc[...] = jnp.zeros_like(z_acc)

    z_acc[...] += jnp.dot(x_ref[0], b_ref[0].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(k == pi - 1)
    def _epilogue():
        z_ref[0] = z_acc[...].astype(z_ref.dtype)


def _backproject_kernel(x_ref, r_ref, out_ref, acc_ref, *, nj: int,
                        inv_n: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0].T, r_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(k == nj - 1)
    def _epilogue():
        out_ref[0] = (-inv_n * acc_ref[...]).astype(out_ref.dtype)


def _check_blocks(n, p, bn, bp):
    assert n % bn == 0 and p % bp == 0, (n, p, bn, bp)
    return n // bn, p // bp


@functools.partial(jax.jit, static_argnames=("bn", "bp", "interpret"))
def logistic_grad_pallas(Xs, ys, B, *, bn: int = 128, bp: int | None = None,
                         interpret: bool = False):
    """Fused all-tasks logistic gradient in ONE pallas call.

    Xs: (m, n, p); ys: (m, n) in {-1, +1}; B: (m, p). Returns g (m, p)
    = -X'(y sigmoid(-y Xb))/n per task. `bn` tiles the sample axis,
    `bp` the feature axis (None = full-lane bp = p). bp == p takes the
    resident single-pass layout; bp < p the two-phase feature-tiled
    sweep (forward matvec carry, reversed back-projection).
    """
    m, n, p = Xs.shape
    bn = min(bn, n)
    bp = p if bp is None else min(bp, p)
    nj, pi = _check_blocks(n, p, bn, bp)
    y_spec = pl.BlockSpec((1, bn, 1), lambda t, j, *k: (t, j, 0))
    out_dtype = jax.ShapeDtypeStruct((m, p, 1), B.dtype)
    if pi == 1:
        x_spec = pl.BlockSpec((1, bn, p), lambda t, j: (t, j, 0))
        task_p = pl.BlockSpec((1, p, 1), lambda t, j: (t, 0, 0))
        out = pl.pallas_call(
            functools.partial(_resident_grad_kernel, nj=nj, inv_n=1.0 / n),
            grid=(m, nj),
            in_specs=[x_spec, y_spec, task_p],
            out_specs=task_p,
            out_shape=out_dtype,
            scratch_shapes=[pltpu.VMEM((p, 1), jnp.float32)],
            interpret=interpret,
        )(Xs, ys[..., None], B[..., None])
        return out[..., 0]

    # feature tile index: forward k in [0, pi), then the backward sweep
    # revisits in reverse so the turnaround tile is still resident
    fi = lambda k: jnp.where(k < pi, k, 2 * pi - 1 - k)
    x_spec = pl.BlockSpec((1, bn, bp), lambda t, j, k: (t, j, fi(k)))
    tile_p = pl.BlockSpec((1, bp, 1), lambda t, j, k: (t, fi(k), 0))
    out = pl.pallas_call(
        functools.partial(_tiled_grad_kernel, pi=pi, nj=nj, inv_n=1.0 / n),
        grid=(m, nj, 2 * pi),
        in_specs=[x_spec, y_spec, tile_p],
        out_specs=tile_p,
        out_shape=out_dtype,
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32),
                        pltpu.VMEM((pi, bp, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, ys[..., None], B[..., None])
    return out[..., 0]


@functools.partial(jax.jit, static_argnames=("bn", "bp", "interpret"))
def logistic_grad_unfused_pallas(Xs, ys, B, *, bn: int = 128,
                                 bp: int | None = None,
                                 interpret: bool = False):
    """The two-dispatch baseline: forward-matvec kernel, jnp residual,
    back-projection kernel. Same (bn, bp) tiles and arithmetic as the
    fused kernel, plus one (m, n) round trip through HBM for the
    residual — the pre-fusion cost the benchmark pair tracks."""
    m, n, p = Xs.shape
    bn = min(bn, n)
    bp = p if bp is None else min(bp, p)
    nj, pi = _check_blocks(n, p, bn, bp)
    z = pl.pallas_call(
        functools.partial(_logistic_z_kernel, pi=pi),
        grid=(m, nj, pi),
        in_specs=[pl.BlockSpec((1, bn, bp), lambda t, j, k: (t, j, k)),
                  pl.BlockSpec((1, bp, 1), lambda t, j, k: (t, k, 0))],
        out_specs=pl.BlockSpec((1, bn, 1), lambda t, j, k: (t, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, B[..., None])[..., 0]
    r = ys * jax.nn.sigmoid(-ys * z.astype(ys.dtype))
    out = pl.pallas_call(
        functools.partial(_backproject_kernel, nj=nj, inv_n=1.0 / n),
        grid=(m, pi, nj),
        in_specs=[pl.BlockSpec((1, bn, bp), lambda t, i, k: (t, k, i)),
                  pl.BlockSpec((1, bn, 1), lambda t, i, k: (t, k, 0))],
        out_specs=pl.BlockSpec((1, bp, 1), lambda t, i, k: (t, i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, p, 1), B.dtype),
        scratch_shapes=[pltpu.VMEM((bp, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, r[..., None])
    return out[..., 0]
