"""Pallas TPU kernel: fused all-tasks logistic gradient.

One dispatch computes, for every task t, the full gradient of the
logistic loss at the current iterate:

    z = X b,   r = y * sigmoid(-y z),   g = -X' r / n.

Tiling (DESIGN.md §11): the grid is (m, nj) — tasks outermost, sample
tiles of `bn` rows innermost. Each (t, j) step loads one (bn, p) slab
of X_t with the FULL feature dimension as the lane axis, so the forward
matvec `X_j @ b`, the sigmoid residual, and the back-projection
`X_j' r_j` all fire on the same resident VMEM tile — X is streamed
exactly once and z/r never round-trip through HBM. The per-task
gradient accumulates in a (p, 1) f32 VMEM scratch across the j sweep
and the epilogue scales by -1/n (a compile-time constant) on the last
sample tile. The layout trades p-tiling for single-pass fusion: a slab
is bn*p elements of VMEM, right for the paper regime (p up to a few
thousand); the dispatcher routes larger/ragged shapes to the jnp
oracle.

`logistic_z_pallas` / `logistic_backproject_pallas` are the UNFUSED
halves (forward matvec only / back-projection of a precomputed
residual). They exist as the two-dispatch baseline the fused kernel is
benchmarked against (benchmarks/kernels_bench.py) — same tiles, same
arithmetic, one extra HBM round trip for the residual.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _logistic_grad_kernel(x_ref, y_ref, b_ref, out_ref, acc_ref, *,
                          nj: int, inv_n: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                        # (bn, p)
    z = jnp.dot(x, b_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)     # (bn, 1)
    y = y_ref[0].astype(jnp.float32)                    # (bn, 1)
    r = y * jax.nn.sigmoid(-y * z)
    acc_ref[...] += jnp.dot(x.T, r,
                            preferred_element_type=jnp.float32)  # (p, 1)

    @pl.when(j == nj - 1)
    def _epilogue():
        out_ref[0] = (-inv_n * acc_ref[...]).astype(out_ref.dtype)


def _logistic_z_kernel(x_ref, b_ref, z_ref):
    z_ref[0] = jnp.dot(x_ref[0], b_ref[0].astype(jnp.float32),
                       preferred_element_type=jnp.float32
                       ).astype(z_ref.dtype)


def _backproject_kernel(x_ref, r_ref, out_ref, acc_ref, *, nj: int,
                        inv_n: float):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0].T, r_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(j == nj - 1)
    def _epilogue():
        out_ref[0] = (-inv_n * acc_ref[...]).astype(out_ref.dtype)


def _grid_specs(m, n, p, bn):
    nj = n // bn
    x_spec = pl.BlockSpec((1, bn, p), lambda t, j: (t, j, 0))
    col_spec = pl.BlockSpec((1, bn, 1), lambda t, j: (t, j, 0))
    task_p_spec = pl.BlockSpec((1, p, 1), lambda t, j: (t, 0, 0))
    return (m, nj), nj, x_spec, col_spec, task_p_spec


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def logistic_grad_pallas(Xs, ys, B, *, bn: int = 128,
                         interpret: bool = False):
    """Fused all-tasks logistic gradient in ONE pallas call.

    Xs: (m, n, p); ys: (m, n) in {-1, +1}; B: (m, p). Returns g (m, p)
    = -X'(y sigmoid(-y Xb))/n per task. `bn` tiles the sample axis; the
    feature axis rides whole in the lane dimension.
    """
    m, n, p = Xs.shape
    bn = min(bn, n)
    assert n % bn == 0, (m, n, p, bn)
    grid, nj, x_spec, col_spec, task_p_spec = _grid_specs(m, n, p, bn)
    out = pl.pallas_call(
        functools.partial(_logistic_grad_kernel, nj=nj, inv_n=1.0 / n),
        grid=grid,
        in_specs=[x_spec, col_spec, task_p_spec],
        out_specs=task_p_spec,
        out_shape=jax.ShapeDtypeStruct((m, p, 1), B.dtype),
        scratch_shapes=[pltpu.VMEM((p, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, ys[..., None], B[..., None])
    return out[..., 0]


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def logistic_grad_unfused_pallas(Xs, ys, B, *, bn: int = 128,
                                 interpret: bool = False):
    """The two-dispatch baseline: forward-matvec kernel, jnp residual,
    back-projection kernel. Same tiles and arithmetic as the fused
    kernel, plus one (m, n) round trip through HBM for the residual —
    the pre-fusion cost the benchmark pair tracks."""
    m, n, p = Xs.shape
    bn = min(bn, n)
    assert n % bn == 0, (m, n, p, bn)
    grid, nj, x_spec, col_spec, task_p_spec = _grid_specs(m, n, p, bn)
    z = pl.pallas_call(
        _logistic_z_kernel,
        grid=grid,
        in_specs=[x_spec, task_p_spec],
        out_specs=col_spec,
        out_shape=jax.ShapeDtypeStruct((m, n, 1), jnp.float32),
        interpret=interpret,
    )(Xs, B[..., None])[..., 0]
    r = ys * jax.nn.sigmoid(-ys * z.astype(ys.dtype))
    out = pl.pallas_call(
        functools.partial(_backproject_kernel, nj=nj, inv_n=1.0 / n),
        grid=grid,
        in_specs=[x_spec, col_spec],
        out_specs=task_p_spec,
        out_shape=jax.ShapeDtypeStruct((m, p, 1), B.dtype),
        scratch_shapes=[pltpu.VMEM((p, 1), jnp.float32)],
        interpret=interpret,
    )(Xs, r[..., None])
    return out[..., 0]
