"""Dispatcher for the fused all-tasks logistic gradient.

Same convention as `kernels/ista_step/ops.py`: the pallas kernel on
MXU-friendly shapes (interpret mode off-TPU so the same BlockSpecs
execute everywhere), the jnp oracle on ragged shapes — and the oracle
is bitwise the engine's historical inline einsum gradient, so routing
never perturbs solver iterates.

Block policy (DESIGN.md §12): `block` is None (budgeted default), an
int sample tile bn, or an explicit (bn, bp) pair — bn tiles the sample
axis, bp the feature axis. Anything else raises (the old dispatcher
documented `block: int` but silently coerced tuples via `block[0]`, so
a rank-style (bp, bn) pair picked the FEATURE tile as the sample
tile). The feature axis no longer has a hard p cliff: the routing
predicate is a per-tile VMEM budget — full-lane slabs while they fit,
feature-tiled slabs past that, the oracle only when no legal tiling
fits (ragged axes, sliver-degraded sample tiles, or p so large the
gradient accumulator itself outgrows the budget).
"""
from __future__ import annotations

from typing import Optional, Tuple

from repro.kernels.common import (
    MIN_TILE, aligned_fit_block, degrades_to_slivers, on_tpu,
    record_route, validate_block,
)
from repro.kernels.common import is_ragged_samples  # re-export (tests/engine)
from repro.kernels.logistic_grad.kernel import (
    logistic_grad_pallas, logistic_grad_unfused_pallas,
)
from repro.kernels.logistic_grad.ref import logistic_grad_ref

# per-dispatch VMEM budget for one grid step of the kernel (half of the
# ~16 MB/core, leaving slack for operand double-buffering). With the
# default bn = 128, full-lane slabs fit to p ~= 2.7k; past that the
# kernel feature-tiles (the whole old MAX_FULL_LANE_P regime stays on
# the kernel — tiled instead of falling off a cliff onto the oracle);
# only p whose PADDED gradient accumulator alone busts the budget
# (p ≳ 16k, see below) routes away entirely
LOGISTIC_VMEM_BUDGET = 8 * 1024 * 1024


def kernel_vmem_bytes(p: int, bn: int, bp: int) -> int:
    """Estimated VMEM footprint of one fused-kernel grid step. The
    (bn, bp) X slab is counted double-buffered at its true f32 size;
    every trailing-singleton buffer — the gradient accumulator (p rows
    total across its pi tiles), the z carry and y tile (bn rows), the
    b and out tiles (bp rows) — is counted at its PADDED width: a
    (r, 1) f32 buffer occupies full (8, 128) register tiles on TPU,
    i.e. 512 bytes per row, not 4. Only the bn TILE of the sample axis
    is resident, so n itself never enters."""
    return 8 * bn * bp + 512 * (p + 2 * bn + 3 * bp)


# `block=` normalization: the shared validator's partial-arity mode.
# block=None defaults both axes, a bare int is a bn request with the
# feature tile budgeted (NOT broadcast — tuples must spell out both
# entries), a (bn, bp) pair is taken whole; a returned None request
# means "use the budgeted default for that axis". Note the tuple
# order: bn (sample axis) first, bp (feature axis) second — a
# rank_update-style (bp, bn) pair would tile the wrong axes, which is
# exactly the silent `block[0]` coercion this validation replaces.
_BLOCK_ARITIES = (0, 1, 2)


def _budget_bp(p: int, bn: int) -> int:
    """Largest aligned-divisor feature tile whose grid step fits the
    VMEM budget — bp = p (the resident full-lane layout) whenever it
    fits."""
    bp = aligned_fit_block(p, min(p, max(LOGISTIC_VMEM_BUDGET // (8 * bn),
                                         8)))
    while kernel_vmem_bytes(p, bn, bp) > LOGISTIC_VMEM_BUDGET and bp > 8:
        bp = aligned_fit_block(p, bp - 1)
    return bp


def resolve_logistic_blocks(n: int, p: int, block=None) -> Tuple[int, int]:
    """Normalize a block policy to concrete (bn, bp) tile sizes.

    `block` is None (bn = 128 request, bp budgeted), an int bn request,
    or an explicit (bn, bp) pair — e.g. an autotuned winner from
    `repro.kernels.autotune.autotune_logistic_block`. Each entry is
    clipped to the largest 8-ALIGNED divisor of its dimension — the
    tile the TPU grid can actually use, and the same notion of "legal"
    the routing predicate judges by (a plain divisor scan can land on
    alignment traps like 126 for size 504); a defaulted bp is the
    largest such divisor whose slab fits `LOGISTIC_VMEM_BUDGET` (full
    lanes for small p — the historical layout — feature tiles past it).
    """
    bn_req, bp_req = validate_block(block, 2, "(bn, bp)",
                                    arities=_BLOCK_ARITIES)
    bn = aligned_fit_block(n, 128 if bn_req is None else bn_req)
    bp = _budget_bp(p, bn) if bp_req is None \
        else aligned_fit_block(p, bp_req)
    return bn, bp


def _route_and_resolve(n: int, p: int,
                       block) -> Tuple[Optional[str], int, int]:
    """ONE block resolution feeding both the routing verdict and the
    dispatch tiles, so the predicate can never approve a tiling the
    dispatcher then resolves differently. Returns (reason, bn, bp)
    where reason is None on the kernel path, else the telemetry label
    for why the oracle won. Routed when: ragged axes (`ragged`);
    sample tiles degraded to slivers vs the request (e.g. n = 1016 =
    8*127 against the 128 default) or an explicitly requested feature
    tile that degrades the same way (`sliver`); a resolved tiling over
    the per-tile VMEM budget — only p so large the gradient accumulator
    outgrows it, by construction (`vmem_budget`); or a budgeted default
    bp that itself collapsed to a sliver under the budget (p past the
    full-lane regime with no mid-size aligned divisor, e.g. p = 8168 =
    8*1021 resolves to bp = 8; also `sliver`). The clause SET is what
    routes; the order only picks which label wins when several apply
    (the over-budget p >= 16384 regime also collapses its default bp,
    and `vmem_budget` is the informative cause)."""
    bn_req, bp_req = validate_block(block, 2, "(bn, bp)",
                                    arities=_BLOCK_ARITIES)
    bn, bp = resolve_logistic_blocks(n, p, block)
    if is_ragged_samples(n, p):
        reason = "ragged"
    elif (degrades_to_slivers(n, 128 if bn_req is None else bn_req)
          or (bp_req is not None and degrades_to_slivers(p, bp_req))):
        reason = "sliver"
    elif kernel_vmem_bytes(p, bn, bp) > LOGISTIC_VMEM_BUDGET:
        reason = "vmem_budget"
    elif bp_req is None and bp < min(p, MIN_TILE):
        reason = "sliver"
    else:
        reason = None
    return reason, bn, bp


def routes_to_oracle(n: int, p: int, block=None) -> bool:
    """True when this (n, p) never reaches the pallas kernel (see
    `_route_and_resolve` for the clauses). The engine's block policy
    shares this so it never sweeps a shape the dispatcher will not
    serve."""
    return _route_and_resolve(n, p, block)[0] is not None


def logistic_grad(Xs, ys, B, *, block=None,
                  interpret: bool | None = None):
    """All-tasks logistic gradient -X'(y sigmoid(-y Xb))/n.

    Xs (m, n, p), ys (m, n) in {-1, +1}, B (m, p) -> (m, p). `block` is
    None, an int sample tile bn, or a (bn, bp) pair (e.g. an autotuned
    winner from `repro.kernels.autotune.autotune_logistic_block`);
    ragged, sliver-degraded, and over-VMEM-budget shapes fall back to
    `logistic_grad_ref`.
    """
    m, n, p = Xs.shape
    interp = (not on_tpu()) if interpret is None else interpret
    reason, bn, bp = _route_and_resolve(n, p, block)
    record_route("logistic_grad", reason, blocks=(bn, bp))
    if reason is not None:
        return logistic_grad_ref(Xs, ys, B)
    return logistic_grad_pallas(Xs, ys, B, bn=bn, bp=bp, interpret=interp)


def logistic_grad_unfused(Xs, ys, B, *, block=None,
                          interpret: bool | None = None):
    """Two-dispatch (matvec + back-projection) pallas baseline with the
    same routing policy — exists for the fused-vs-unfused benchmark pair
    and as a second kernel-path parity anchor in tests."""
    m, n, p = Xs.shape
    interp = (not on_tpu()) if interpret is None else interpret
    reason, bn, bp = _route_and_resolve(n, p, block)
    record_route("logistic_grad_unfused", reason, blocks=(bn, bp))
    if reason is not None:
        return logistic_grad_ref(Xs, ys, B)
    return logistic_grad_unfused_pallas(Xs, ys, B, bn=bn, bp=bp,
                                        interpret=interp)
