"""Dispatcher for the fused all-tasks logistic gradient.

Same convention as `kernels/ista_step/ops.py`: the pallas kernel on
MXU-friendly shapes (interpret mode off-TPU so the same BlockSpecs
execute everywhere), the jnp oracle on ragged shapes — and the oracle
is bitwise the engine's historical inline einsum gradient, so routing
never perturbs solver iterates.
"""
from __future__ import annotations

from repro.kernels.common import fit_block, is_ragged_samples, on_tpu
from repro.kernels.logistic_grad.kernel import (
    logistic_grad_pallas, logistic_grad_unfused_pallas,
)
from repro.kernels.logistic_grad.ref import logistic_grad_ref

# the kernel keeps the FULL feature axis resident per X slab (see
# kernel.py); past this p the slab outgrows its VMEM budget, so the
# dispatcher honours the documented "larger shapes belong to the
# oracle" contract instead of failing Mosaic compilation
MAX_FULL_LANE_P = 4096


def routes_to_oracle(n: int, p: int) -> bool:
    """True when this (n, p) never reaches the pallas kernel — ragged,
    or feature axis too large for a resident full-p slab. The engine's
    block policy shares this so it never sweeps a shape the dispatcher
    will not serve."""
    return is_ragged_samples(n, p) or p > MAX_FULL_LANE_P


def logistic_grad(Xs, ys, B, *, block: int = 128,
                  interpret: bool | None = None):
    """All-tasks logistic gradient -X'(y sigmoid(-y Xb))/n.

    Xs (m, n, p), ys (m, n) in {-1, +1}, B (m, p) -> (m, p). `block`
    (an int `bn`, e.g. an autotuned winner from `repro.kernels.
    autotune.autotune_logistic_block`) tiles the sample axis; ragged
    and larger-than-VMEM-slab shapes fall back to `logistic_grad_ref`.
    """
    m, n, p = Xs.shape
    interp = (not on_tpu()) if interpret is None else interpret
    if routes_to_oracle(n, p):
        return logistic_grad_ref(Xs, ys, B)
    bn = fit_block(n, block if isinstance(block, int) else block[0])
    return logistic_grad_pallas(Xs, ys, B, bn=bn, interpret=interp)


def logistic_grad_unfused(Xs, ys, B, *, block: int = 128,
                          interpret: bool | None = None):
    """Two-dispatch (matvec + back-projection) pallas baseline with the
    same routing policy — exists for the fused-vs-unfused benchmark pair
    and as a second kernel-path parity anchor in tests."""
    m, n, p = Xs.shape
    interp = (not on_tpu()) if interpret is None else interpret
    if routes_to_oracle(n, p):
        return logistic_grad_ref(Xs, ys, B)
    bn = fit_block(n, block if isinstance(block, int) else block[0])
    return logistic_grad_unfused_pallas(Xs, ys, B, bn=bn, interpret=interp)
