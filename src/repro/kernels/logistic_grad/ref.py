"""Pure-jnp oracle for the fused all-tasks logistic gradient.

The batched l1-logistic FISTA loop (core/engine.solve_logistic_lasso_
batched) spends its whole iteration on

    z = X @ b            (forward einsum)
    r = y * sigmoid(-y z)  (residual)
    g = -X' r / n          (back-projection)

for all m tasks at once. This oracle IS the engine's historical inline
gradient (bitwise — the dispatcher's CPU path must not perturb the
solver iterates) and the reference the Pallas kernel is tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def logistic_grad_ref(Xs: jnp.ndarray, ys: jnp.ndarray,
                      B: jnp.ndarray) -> jnp.ndarray:
    """All-tasks logistic gradient. Xs (m, n, p), ys (m, n) in {-1, +1},
    B (m, p) -> g (m, p) with g_t = -X_t'(y_t sigmoid(-y_t X_t b_t))/n."""
    n = Xs.shape[1]
    z = jnp.einsum("tnp,tp->tn", Xs, B)
    return -jnp.einsum("tnp,tn->tp", Xs,
                       ys * jax.nn.sigmoid(-ys * z)) / n
