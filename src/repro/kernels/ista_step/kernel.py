"""Pallas TPU kernel: fused ISTA step (matmul + gradient step + prox).

Tiling: the output (p, r) is tiled (BP, BR); the contraction over p runs
as the innermost grid dimension with a VMEM f32 scratch accumulator —
each (i, j) output tile accumulates Sigma[i, :] @ beta[:, j] over k-tiles
on the MXU, then the epilogue (gradient step + soft threshold, VPU ops)
fires on the last k step. Tiles default to 128 (MXU-aligned); the scalars
(eta, lam) ride in SMEM.

`ista_step_batched_pallas` extends the same tiling with a leading task
grid dimension: all m per-task solves of the DSML hot loop run as one
pallas call over per-task Sigma tiles and per-task step sizes (SMEM).

`fista_step_batched_pallas` is the engine-v2 variant: the epilogue also
applies the FISTA momentum extrapolation, emitting BOTH the prox'd
iterate `x_next` and the look-ahead point `z_next = x_next +
theta (x_next - x_prev)` from the same VMEM tiles — one kernel dispatch
and one HBM round trip per FISTA iteration where the two-op path paid a
kernel plus a separate jnp momentum pass over (m, p, r). The momentum
coefficient `theta` rides in SMEM next to `etas`/`lam`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ista_kernel(eta_lam_ref, sig_ref, beta_ref, beta_tile_ref, c_ref,
                 out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(sig_ref[...], beta_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = eta_lam_ref[0]
        lam = eta_lam_ref[1]
        grad = acc_ref[...] - c_ref[...].astype(jnp.float32)
        z = beta_tile_ref[...].astype(jnp.float32) - eta * grad
        tau = eta * lam
        out = jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)
        out_ref[...] = out.astype(out_ref.dtype)


def _ista_batched_kernel(eta_lam_ref, sig_ref, beta_ref, beta_tile_ref,
                         c_ref, out_ref, acc_ref, *, nk: int, m: int):
    t = pl.program_id(0)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(sig_ref[0], beta_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = eta_lam_ref[t]            # per-task step size
        lam = eta_lam_ref[m + t]        # per-task regularization weight
        grad = acc_ref[...] - c_ref[0].astype(jnp.float32)
        z = beta_tile_ref[0].astype(jnp.float32) - eta * grad
        tau = eta * lam
        out = jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)
        out_ref[0] = out.astype(out_ref.dtype)


def _fista_batched_kernel(scal_ref, sig_ref, z_ref, z_tile_ref, x_ref,
                          c_ref, xn_ref, zn_ref, acc_ref, *, nk: int,
                          m: int):
    t = pl.program_id(0)
    k = pl.program_id(3)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(sig_ref[0], z_ref[0],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = scal_ref[t]               # per-task step size
        lam = scal_ref[m + t]           # per-task regularization weight
        theta = scal_ref[2 * m]         # momentum coefficient (t_j-1)/t_{j+1}
        grad = acc_ref[...] - c_ref[0].astype(jnp.float32)
        v = z_tile_ref[0].astype(jnp.float32) - eta * grad
        tau = eta * lam
        xn = (jnp.sign(v) * jnp.maximum(jnp.abs(v) - tau, 0.0)
              ).astype(xn_ref.dtype)
        xn_ref[0] = xn
        # momentum in the iterate dtype, on the already-cast x_next —
        # bitwise what the two-op path computes from the kernel output
        zn_ref[0] = xn + theta.astype(xn.dtype) * (xn - x_ref[0])


@functools.partial(jax.jit,
                   static_argnames=("bp", "br", "bk", "interpret"))
def fista_step_batched_pallas(Sigmas, zs, xs, cs, etas, lam, theta, *,
                              bp: int = 128, br: int = 128, bk: int = 128,
                              interpret: bool = False):
    """One fused FISTA iteration for m tasks: prox step at the momentum
    point `zs` plus the extrapolation against the previous iterate `xs`.

    Sigmas: (m, p, p); zs/xs/cs: (m, p, r); etas: (m,) per-task step
    sizes; lam scalar or per-task (m,); theta the (traced) scalar
    momentum coefficient of this iteration. Returns (x_next, z_next),
    both (m, p, r).
    """
    m, p, r = zs.shape
    bp = min(bp, p)
    br = min(br, r)
    bk = min(bk, p)
    assert p % bp == 0 and r % br == 0 and p % bk == 0, (m, p, r, bp, br, bk)
    ni, nj, nk = p // bp, r // br, p // bk

    scal = jnp.concatenate(
        [etas.astype(jnp.float32).reshape(m),
         jnp.broadcast_to(jnp.asarray(lam, jnp.float32).reshape(-1), (m,)),
         jnp.asarray(theta, jnp.float32).reshape(1)])

    out = jax.ShapeDtypeStruct((m, p, r), zs.dtype)
    tile = pl.BlockSpec((1, bp, br), lambda t, i, j, k: (t, i, j))
    return pl.pallas_call(
        functools.partial(_fista_batched_kernel, nk=nk, m=m),
        grid=(m, ni, nj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # etas ++ lam ++ [theta]
            pl.BlockSpec((1, bp, bk), lambda t, i, j, k: (t, i, k)),
            pl.BlockSpec((1, bk, br), lambda t, i, j, k: (t, k, j)),
            tile,                                   # z (iterate tile)
            tile,                                   # x_prev
            tile,                                   # c
        ],
        out_specs=(tile, tile),
        out_shape=(out, out),
        scratch_shapes=[pltpu.VMEM((bp, br), jnp.float32)],
        interpret=interpret,
    )(scal, Sigmas, zs, zs, xs, cs)


@functools.partial(jax.jit,
                   static_argnames=("bp", "br", "bk", "interpret"))
def ista_step_batched_pallas(Sigmas, betas, cs, etas, lam, *, bp: int = 128,
                             br: int = 128, bk: int = 128,
                             interpret: bool = False):
    """Batched fused ISTA step over m independent tasks in ONE pallas call.

    Sigmas: (m, p, p), betas/cs: (m, p, r), etas: (m,) per-task step
    sizes, lam scalar or per-task (m,) regularization weights. The task
    index is the outermost grid dimension, so every task's (i, j, k)
    tile sweep reuses the same VMEM accumulator layout as the
    single-task kernel — the MXU sees one long stream of
    (bp, bk) x (bk, br) tiles instead of m separate dispatches.
    """
    m, p, r = betas.shape
    bp = min(bp, p)
    br = min(br, r)
    bk = min(bk, p)
    assert p % bp == 0 and r % br == 0 and p % bk == 0, (m, p, r, bp, br, bk)
    ni, nj, nk = p // bp, r // br, p // bk

    eta_lam = jnp.concatenate(
        [etas.astype(jnp.float32).reshape(m),
         jnp.broadcast_to(jnp.asarray(lam, jnp.float32).reshape(-1),
                          (m,))])

    return pl.pallas_call(
        functools.partial(_ista_batched_kernel, nk=nk, m=m),
        grid=(m, ni, nj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),        # etas ++ [lam]
            pl.BlockSpec((1, bp, bk), lambda t, i, j, k: (t, i, k)),
            pl.BlockSpec((1, bk, br), lambda t, i, j, k: (t, k, j)),
            pl.BlockSpec((1, bp, br), lambda t, i, j, k: (t, i, j)),
            pl.BlockSpec((1, bp, br), lambda t, i, j, k: (t, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bp, br), lambda t, i, j, k: (t, i, j)),
        out_shape=jax.ShapeDtypeStruct((m, p, r), betas.dtype),
        scratch_shapes=[pltpu.VMEM((bp, br), jnp.float32)],
        interpret=interpret,
    )(eta_lam, Sigmas, betas, betas, cs)


@functools.partial(jax.jit,
                   static_argnames=("bp", "br", "bk", "interpret"))
def ista_step_pallas(Sigma, beta, c, eta, lam, *, bp: int = 128,
                     br: int = 128, bk: int = 128,
                     interpret: bool = False):
    """Sigma: (p, p), beta/c: (p, r). Returns the next ISTA iterate (p, r)."""
    p, r = beta.shape
    bp = min(bp, p)
    br = min(br, r)
    bk = min(bk, p)
    assert p % bp == 0 and r % br == 0 and p % bk == 0, (p, r, bp, br, bk)
    ni, nj, nk = p // bp, r // br, p // bk

    eta_lam = jnp.array([eta, lam], jnp.float32)

    return pl.pallas_call(
        functools.partial(_ista_kernel, nk=nk),
        grid=(ni, nj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # (eta, lam)
            pl.BlockSpec((bp, bk), lambda i, j, k: (i, k)),   # Sigma tile
            pl.BlockSpec((bk, br), lambda i, j, k: (k, j)),   # beta (contraction)
            pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),   # beta (iterate)
            pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),   # c tile
        ],
        out_specs=pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, r), beta.dtype),
        scratch_shapes=[pltpu.VMEM((bp, br), jnp.float32)],
        interpret=interpret,
    )(eta_lam, Sigma, beta, beta, c)
