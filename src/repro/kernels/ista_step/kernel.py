"""Pallas TPU kernel: fused ISTA step (matmul + gradient step + prox).

Tiling: the output (p, r) is tiled (BP, BR); the contraction over p runs
as the innermost grid dimension with a VMEM f32 scratch accumulator —
each (i, j) output tile accumulates Sigma[i, :] @ beta[:, j] over k-tiles
on the MXU, then the epilogue (gradient step + soft threshold, VPU ops)
fires on the last k step. Tiles default to 128 (MXU-aligned); the scalars
(eta, lam) ride in SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ista_kernel(eta_lam_ref, sig_ref, beta_ref, beta_tile_ref, c_ref,
                 out_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(sig_ref[...], beta_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        eta = eta_lam_ref[0]
        lam = eta_lam_ref[1]
        grad = acc_ref[...] - c_ref[...].astype(jnp.float32)
        z = beta_tile_ref[...].astype(jnp.float32) - eta * grad
        tau = eta * lam
        out = jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)
        out_ref[...] = out.astype(out_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bp", "br", "bk", "interpret"))
def ista_step_pallas(Sigma, beta, c, eta, lam, *, bp: int = 128,
                     br: int = 128, bk: int = 128,
                     interpret: bool = False):
    """Sigma: (p, p), beta/c: (p, r). Returns the next ISTA iterate (p, r)."""
    p, r = beta.shape
    bp = min(bp, p)
    br = min(br, r)
    bk = min(bk, p)
    assert p % bp == 0 and r % br == 0 and p % bk == 0, (p, r, bp, br, bk)
    ni, nj, nk = p // bp, r // br, p // bk

    eta_lam = jnp.array([eta, lam], jnp.float32)

    return pl.pallas_call(
        functools.partial(_ista_kernel, nk=nk),
        grid=(ni, nj, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # (eta, lam)
            pl.BlockSpec((bp, bk), lambda i, j, k: (i, k)),   # Sigma tile
            pl.BlockSpec((bk, br), lambda i, j, k: (k, j)),   # beta (contraction)
            pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),   # beta (iterate)
            pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),   # c tile
        ],
        out_specs=pl.BlockSpec((bp, br), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, r), beta.dtype),
        scratch_shapes=[pltpu.VMEM((bp, br), jnp.float32)],
        interpret=interpret,
    )(eta_lam, Sigma, beta, beta, c)
