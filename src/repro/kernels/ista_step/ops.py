"""Jit'd public wrapper for the fused ISTA step.

On CPU (this container) the kernel body executes in interpret mode; on a
real TPU the same BlockSpecs compile to Mosaic. `ista_solve` runs a whole
FISTA-free proximal-gradient loop with the fused kernel as the body —
the drop-in accelerated path for core/solvers.lasso and
core/debias.inverse_hessian_m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ista_step.kernel import (
    ista_step_batched_pallas, ista_step_pallas,
)
from repro.kernels.ista_step.ref import ista_step_batched_ref, ista_step_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _fit_block(size: int, block: int) -> int:
    b = min(block, size)
    while size % b:
        b //= 2
    return b


def ista_step_batched(Sigmas, betas, cs, etas, lam, *, block: int = 128,
                      interpret: bool | None = None):
    """One fused ISTA step for m tasks. Sigmas (m, p, p); betas, cs
    (m, p) or (m, p, r); etas (m,) per-task step sizes; lam scalar or
    per-task (m,).

    Routes to the batched pallas kernel on MXU-friendly shapes (ragged
    shapes fall back to the batched jnp oracle); `interpret` defaults to
    True off-TPU so the same BlockSpecs execute everywhere.
    """
    squeeze = betas.ndim == 2
    if squeeze:
        betas = betas[..., None]
        cs = cs[..., None]
    m, p, r = betas.shape
    interp = (not _on_tpu()) if interpret is None else interpret
    if p % 8 or (r % 8 and r != 1):
        out = ista_step_batched_ref(Sigmas, betas, cs, etas, lam)
    else:
        bp = _fit_block(p, block)
        br = _fit_block(r, block)
        out = ista_step_batched_pallas(Sigmas, betas, cs, etas, lam,
                                       bp=bp, br=br, bk=bp, interpret=interp)
    return out[..., 0] if squeeze else out


def ista_step(Sigma, beta, c, eta, lam, *, block: int = 128,
              interpret: bool | None = None):
    """One fused ISTA step. Shapes: Sigma (p,p); beta, c (p,) or (p,r)."""
    squeeze = beta.ndim == 1
    if squeeze:
        beta = beta[:, None]
        c = c[:, None]
    p, r = beta.shape
    interp = (not _on_tpu()) if interpret is None else interpret
    if p % 8 or (r % 8 and r != 1):
        out = ista_step_ref(Sigma, beta, c, eta, lam)   # ragged fallback
    else:
        bp = _fit_block(p, block)
        br = _fit_block(r, block)
        out = ista_step_pallas(Sigma, beta, c, eta, lam, bp=bp, br=br,
                               bk=bp, interpret=interp)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def ista_solve(Sigma, c, lam, *, iters: int = 400, block: int = 128,
               interpret: bool | None = None):
    """Proximal-gradient lasso solve on sufficient statistics via the
    fused kernel: min_b 1/2 b'Sigma b - c'b + lam|b|_1 (multi-RHS)."""
    from repro.core.solvers import power_iteration
    eta = 1.0 / jnp.maximum(power_iteration(Sigma), 1e-12)
    beta0 = jnp.zeros_like(c)

    def body(_, beta):
        return ista_step(Sigma, beta, c, eta, lam, block=block,
                         interpret=interpret)

    return jax.lax.fori_loop(0, iters, body, beta0)
