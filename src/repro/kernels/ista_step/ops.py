"""Jit'd public wrapper for the fused ISTA step.

On CPU (this container) the kernel body executes in interpret mode; on a
real TPU the same BlockSpecs compile to Mosaic. `ista_solve` runs a whole
FISTA-free proximal-gradient loop with the fused kernel as the body —
the drop-in accelerated path for core/solvers.lasso and
core/debias.inverse_hessian_m.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import (
    aligned_fit_block, record_route, validate_block,
)
from repro.kernels.common import on_tpu as _on_tpu
from repro.kernels.ista_step.kernel import (
    fista_step_batched_pallas, ista_step_batched_pallas, ista_step_pallas,
)
from repro.kernels.ista_step.ref import (
    fista_step_batched_ref, ista_step_batched_ref, ista_step_ref,
)


def is_ragged(p: int, r: int) -> bool:
    """THE kernel routing predicate: shapes the pallas tiling cannot
    legally cover go to the jnp oracle (which ignores blocks). Shared
    by the step dispatchers below and the engine's block policy so the
    two can never desync."""
    return bool(p % 8 or (r % 8 and r != 1))


def resolve_blocks(p: int, r: int, block) -> tuple:
    """Normalize a block policy to concrete (bp, br, bk) tile sizes.

    `block` is either one int (square bp = bk tiles, the historical
    policy) or an explicit (bp, br, bk) triple, e.g. an autotuned winner
    from `repro.kernels.autotune`; each entry is clipped to the largest
    aligned divisor of its dimension so ragged-adjacent shapes stay
    legal (the old local halving clip bottomed non-divisor requests
    like 48-on-80 out at single-element tiles).
    Anything else raises — a wrong-arity tuple (e.g. a (bp, bn) rank
    pair) must not be silently unpacked into the wrong axes.
    """
    bp, br, bk = validate_block(block, 3, "(bp, br, bk)")
    return (aligned_fit_block(p, bp), aligned_fit_block(r, br),
            aligned_fit_block(p, bk))


def ista_step_batched(Sigmas, betas, cs, etas, lam, *, block: int = 128,
                      interpret: bool | None = None):
    """One fused ISTA step for m tasks. Sigmas (m, p, p); betas, cs
    (m, p) or (m, p, r); etas (m,) per-task step sizes; lam scalar or
    per-task (m,).

    Routes to the batched pallas kernel on MXU-friendly shapes (ragged
    shapes fall back to the batched jnp oracle); `interpret` defaults to
    True off-TPU so the same BlockSpecs execute everywhere.
    """
    squeeze = betas.ndim == 2
    if squeeze:
        betas = betas[..., None]
        cs = cs[..., None]
    m, p, r = betas.shape
    # resolve (and so validate) blocks before the ragged short-circuit:
    # a malformed block must raise on every path
    bp, br, bk = resolve_blocks(p, r, block)
    interp = (not _on_tpu()) if interpret is None else interpret
    record_route("ista_step_batched", "ragged" if is_ragged(p, r) else None,
                 blocks=(bp, br, bk))
    if is_ragged(p, r):
        out = ista_step_batched_ref(Sigmas, betas, cs, etas, lam)
    else:
        out = ista_step_batched_pallas(Sigmas, betas, cs, etas, lam,
                                       bp=bp, br=br, bk=bk, interpret=interp)
    return out[..., 0] if squeeze else out


def fista_step_batched(Sigmas, zs, xs, cs, etas, lam, theta, *,
                       block=128, interpret: bool | None = None):
    """One fused FISTA iteration (prox step + momentum extrapolation)
    for m tasks. Sigmas (m, p, p); zs/xs/cs (m, p) or (m, p, r); etas
    (m,); lam scalar or per-task (m,); theta the scalar momentum
    coefficient. Returns (x_next, z_next).

    Same routing policy as `ista_step_batched`: pallas on MXU-friendly
    shapes (`block` is an int or an autotuned (bp, br, bk) triple),
    batched-jnp oracle on ragged shapes, interpret mode off-TPU.
    """
    squeeze = zs.ndim == 2
    if squeeze:
        zs, xs, cs = zs[..., None], xs[..., None], cs[..., None]
    m, p, r = zs.shape
    bp, br, bk = resolve_blocks(p, r, block)    # validate on every path
    interp = (not _on_tpu()) if interpret is None else interpret
    record_route("fista_step_batched", "ragged" if is_ragged(p, r) else None,
                 blocks=(bp, br, bk))
    if is_ragged(p, r):
        xn, zn = fista_step_batched_ref(Sigmas, zs, xs, cs, etas, lam, theta)
    else:
        xn, zn = fista_step_batched_pallas(Sigmas, zs, xs, cs, etas, lam,
                                           theta, bp=bp, br=br, bk=bk,
                                           interpret=interp)
    return (xn[..., 0], zn[..., 0]) if squeeze else (xn, zn)


def ista_step(Sigma, beta, c, eta, lam, *, block: int = 128,
              interpret: bool | None = None):
    """One fused ISTA step. Shapes: Sigma (p,p); beta, c (p,) or (p,r)."""
    squeeze = beta.ndim == 1
    if squeeze:
        beta = beta[:, None]
        c = c[:, None]
    p, r = beta.shape
    bp, br, bk = resolve_blocks(p, r, block)    # validate on every path
    interp = (not _on_tpu()) if interpret is None else interpret
    record_route("ista_step", "ragged" if is_ragged(p, r) else None,
                 blocks=(bp, br, bk))
    if is_ragged(p, r):
        out = ista_step_ref(Sigma, beta, c, eta, lam)   # ragged fallback
    else:
        out = ista_step_pallas(Sigma, beta, c, eta, lam, bp=bp, br=br,
                               bk=bk, interpret=interp)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def ista_solve(Sigma, c, lam, *, iters: int = 400, block: int = 128,
               interpret: bool | None = None):
    """Proximal-gradient lasso solve on sufficient statistics via the
    fused kernel: min_b 1/2 b'Sigma b - c'b + lam|b|_1 (multi-RHS)."""
    from repro.core.solvers import power_iteration
    eta = 1.0 / jnp.maximum(power_iteration(Sigma), 1e-12)
    beta0 = jnp.zeros_like(c)

    def body(_, beta):
        return ista_step(Sigma, beta, c, eta, lam, block=block,
                         interpret=interpret)

    return jax.lax.fori_loop(0, iters, body, beta0)
