"""Pure-jnp oracle for the fused ISTA step.

One proximal-gradient iteration of the lasso on precomputed sufficient
statistics (the hot loop of DSML's local solve and of the M-matrix
estimation — see core/solvers.py):

    beta' = soft_threshold(beta - eta * (Sigma @ beta - c), eta * lam)

Sigma: (p, p), beta/c: (p, n_rhs) — the multi-RHS form covers both the
lasso (n_rhs=1) and the debias M-matrix (n_rhs=p) solves.
"""
from __future__ import annotations

import jax.numpy as jnp


def ista_step_ref(Sigma: jnp.ndarray, beta: jnp.ndarray, c: jnp.ndarray,
                  eta: float, lam: float) -> jnp.ndarray:
    grad = Sigma @ beta - c
    z = beta - eta * grad
    tau = eta * lam
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)
