"""Pure-jnp oracle for the fused ISTA step.

One proximal-gradient iteration of the lasso on precomputed sufficient
statistics (the hot loop of DSML's local solve and of the M-matrix
estimation — see core/solvers.py):

    beta' = soft_threshold(beta - eta * (Sigma @ beta - c), eta * lam)

Sigma: (p, p), beta/c: (p, n_rhs) — the multi-RHS form covers both the
lasso (n_rhs=1) and the debias M-matrix (n_rhs=p) solves.
"""
from __future__ import annotations

import jax.numpy as jnp


def ista_step_ref(Sigma: jnp.ndarray, beta: jnp.ndarray, c: jnp.ndarray,
                  eta: float, lam: float) -> jnp.ndarray:
    grad = Sigma @ beta - c
    z = beta - eta * grad
    tau = eta * lam
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)


def ista_step_batched_ref(Sigmas: jnp.ndarray, betas: jnp.ndarray,
                          cs: jnp.ndarray, etas: jnp.ndarray,
                          lam) -> jnp.ndarray:
    """Batched oracle: Sigmas (m, p, p), betas/cs (m, p, r), etas (m,),
    lam scalar or per-task (m,).

    One XLA batched matmul for all m tasks — also the fast CPU path of
    the engine (core/engine.py), where pallas runs in interpret mode.
    """
    grad = jnp.einsum("tij,tjr->tir", Sigmas, betas) - cs
    eta = etas.reshape(-1, 1, 1).astype(betas.dtype)
    z = betas - eta * grad
    tau = eta * jnp.asarray(lam, betas.dtype).reshape(-1, 1, 1)
    return jnp.sign(z) * jnp.maximum(jnp.abs(z) - tau, 0.0)


def fista_step_batched_ref(Sigmas: jnp.ndarray, zs: jnp.ndarray,
                           xs: jnp.ndarray, cs: jnp.ndarray,
                           etas: jnp.ndarray, lam, theta):
    """Fused FISTA iteration oracle: the ISTA prox step at the momentum
    point `zs` followed by the extrapolation against the previous
    iterate `xs`,

        x' = soft(z - eta (Sigma z - c), eta lam)
        z' = x' + theta (x' - x)

    Same shapes as `ista_step_batched_ref` plus xs (m, p, r) and the
    scalar momentum coefficient `theta`. Returns (x_next, z_next). The
    arithmetic is the kernel epilogue's, so the engine's CPU fast path
    reproduces the two-op (step + jnp momentum) iterates bitwise.
    """
    x_next = ista_step_batched_ref(Sigmas, zs, cs, etas, lam)
    z_next = x_next + jnp.asarray(theta, x_next.dtype) * (x_next - xs)
    return x_next, z_next
