"""Training step: cross-entropy loss + AdamW update, sharding-aware.

`make_train_step(cfg)` returns a pure function
    train_step(state, batch) -> (state, metrics)
suitable for `jax.jit` with in/out shardings from `repro.sharding`.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import Batch, forward_train, init_params
from repro.models.config import ModelConfig
from repro.optim.adamw import (
    AdamWState, adamw_init, adamw_update, warmup_cosine,
)


class TrainState(NamedTuple):
    params: dict
    opt: AdamWState
    step: jnp.ndarray


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  pspec=None, vocab: Optional[int] = None) -> jnp.ndarray:
    """Token-mean CE in float32; labels == -1 are masked out.

    logits: (B, S, Vp) (vocab-sharded, possibly padded — pad columns are
    masked so the loss is exact); labels: (B, S).
    """
    if pspec is not None:
        logits = jax.lax.with_sharding_constraint(logits, pspec)
    logits = logits.astype(jnp.float32)
    if vocab is not None and vocab < logits.shape[-1]:
        pad_mask = jnp.arange(logits.shape[-1]) < vocab
        logits = jnp.where(pad_mask, logits, -1e30)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None],
                             axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = (lse - ll) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True, logits_pspec=None):
    def loss_fn(params, batch: Batch):
        logits, aux = forward_train(params, cfg, batch, remat=remat)
        ce = cross_entropy(logits, batch.labels, logits_pspec,
                           vocab=cfg.vocab)
        return ce + aux, {"ce": ce, "aux": aux}
    return loss_fn


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    weight_decay: float = 0.1, clip_norm: float = 1.0,
                    remat: bool = True, logits_pspec=None,
                    microbatches: int = 1, grads_pspec=None):
    """`microbatches > 1` enables gradient accumulation (peak activation
    memory drops by the same factor). `grads_pspec` (usually the ZeRO
    opt specs) keeps the f32 accumulator sharded over `data`."""
    loss_fn = make_loss_fn(cfg, remat=remat, logits_pspec=logits_pspec)

    def constrain(g):
        if grads_pspec is None:
            return g
        return jax.lax.with_sharding_constraint(g, grads_pspec)

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, parts, grads

    def train_step(state: TrainState, batch: Batch):
        if microbatches > 1:
            def split(x):
                if x is None:
                    return None
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = Batch(*(split(x) for x in batch))

            def acc_fn(carry, b):
                loss_a, grads_a = carry
                loss, parts, grads = grads_of(state.params, Batch(*b))
                grads = constrain(jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_a, grads))
                return (loss_a + loss, grads), parts

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (loss, grads), parts = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            parts = jax.tree.map(lambda x: x[-1], parts)
        else:
            loss, parts, grads = grads_of(state.params, batch)
            grads = constrain(jax.tree.map(
                lambda g: g.astype(jnp.float32), grads))

        lr = warmup_cosine(state.step, peak_lr=peak_lr, warmup=warmup,
                           total=total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, lr=lr,
            weight_decay=weight_decay, clip_norm=clip_norm,
            grads_pspec=grads_pspec)
        metrics = {"loss": loss, **parts, **opt_metrics}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def init_train_state(key, cfg: ModelConfig) -> TrainState:
    params = init_params(key, cfg)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32))
