"""DSML as a first-class framework feature: distributed multi-task sparse
probing on frozen backbone features.

Each task (one per machine / data-parallel group) owns its own labelled
data; features come from any zoo backbone's `forward_features`. Tasks run
the paper's Algorithm 1 on (features, targets): local lasso -> debias ->
ONE all-gather of the debiased d-vector -> group hard threshold -> filter.
The result is a set of per-task linear heads that share a common sparse
support over the backbone's feature dimensions — communication-efficient
multi-task readout learning, exactly the paper's estimator with X_t =
pooled features.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.dsml import DsmlResult, dsml_fit, dsml_fit_sharded
from repro.core.engine import solve_lasso_eq2_grid, sufficient_stats
from repro.models import Batch, forward_features
from repro.models.config import ModelConfig


class ProbeData(NamedTuple):
    features: jnp.ndarray     # (m, n, d) pooled features per task
    targets: jnp.ndarray      # (m, n) regression targets


def pool_features(params, cfg: ModelConfig, tokens: jnp.ndarray,
                  frontend: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean-pooled final hidden state per sequence. tokens: (n, S) -> (n, d)."""
    feats = forward_features(params, cfg, Batch(tokens=tokens,
                                                frontend=frontend))
    return jnp.mean(feats.astype(jnp.float32), axis=1)


def standardize(X: jnp.ndarray, eps: float = 1e-6):
    mu = jnp.mean(X, axis=-2, keepdims=True)
    sd = jnp.std(X, axis=-2, keepdims=True) + eps
    return (X - mu) / sd


def sparse_probe_fit(data: ProbeData, *, lam: Optional[float] = None,
                     mu: Optional[float] = None, Lam: Optional[float] = None,
                     mesh=None, axis: str = "task",
                     lasso_iters: int = 400,
                     debias_iters: int = 400) -> DsmlResult:
    """Fit shared-support per-task probes with DSML (Algorithm 1).

    data.features: (m, n, d) — standardized internally. When `mesh` is
    given the fit runs SPMD over `mesh[axis]` with the paper's one-round
    communication; otherwise the single-host reference is used.
    """
    m, n, d = data.features.shape
    X = standardize(data.features)
    base = float(jnp.sqrt(jnp.log(float(d)) / n))
    lam = 4.0 * base if lam is None else lam
    mu = base if mu is None else mu
    if mesh is not None:
        res = dsml_fit_sharded(X, data.targets, lam, mu, Lam or 0.0, mesh,
                               axis=axis, lasso_iters=lasso_iters,
                               debias_iters=debias_iters)
    else:
        res = dsml_fit(X, data.targets, lam, mu, Lam or 0.0,
                       lasso_iters=lasso_iters, debias_iters=debias_iters)
    if Lam is None:
        # default threshold: the largest multiplicative gap in the sorted
        # debiased row norms separates signal rows from the noise bulk
        norms = jnp.linalg.norm(res.beta_u.T, axis=-1)
        top = jnp.sort(norms)[::-1][: max(8, d // 8)]
        ratios = top[:-1] / jnp.maximum(top[1:], 1e-12)
        k = int(jnp.argmax(ratios))
        Lam = float(jnp.sqrt(top[k] * jnp.maximum(top[k + 1], 1e-12)))
        from repro.core.prox import support_from_rows
        support = support_from_rows(res.beta_u.T, Lam)
        res = DsmlResult(beta_tilde=res.beta_u * support[None, :],
                         beta_u=res.beta_u, support=support,
                         beta_local=res.beta_local)
    return res


def probe_predict(res: DsmlResult, features: jnp.ndarray) -> jnp.ndarray:
    """features: (m, n, d) -> predictions (m, n)."""
    X = standardize(features)
    return jnp.einsum("tnd,td->tn", X, res.beta_tilde)


def lasso_probe_sweep(data: ProbeData, lams: jnp.ndarray, *,
                      iters: int = 400) -> jnp.ndarray:
    """Per-task lasso heads for a whole grid of lambdas at once.

    Computes sufficient statistics once, then solves the |lams| x m
    problems as ONE batched engine call (Sigmas tiled across the grid) —
    the tuning sweep the per-task-loop baseline pays |lams| solver runs
    for. Returns (len(lams), m, d).
    """
    X = standardize(data.features)
    Sigmas, cs = sufficient_stats(X, data.targets)
    return solve_lasso_eq2_grid(Sigmas, cs, lams, iters=iters)


def synthetic_probe_tasks(key, params, cfg: ModelConfig, *, m: int = 4,
                          n: int = 64, seq: int = 16,
                          s_active: int = 8) -> tuple[ProbeData, jnp.ndarray]:
    """Build a multi-task probing problem on REAL backbone features:
    random token sequences per task, targets = sparse linear functional
    (shared support, per-task coefficients) of the pooled features + noise."""
    keys = jax.random.split(key, 4)
    d = cfg.d_model
    tokens = jax.random.randint(keys[0], (m, n, seq), 0, cfg.vocab)
    feats = jax.vmap(lambda t: pool_features(params, cfg, t))(tokens)
    Xs = standardize(feats)
    perm = jax.random.permutation(keys[1], d)
    support = jnp.zeros(d, bool).at[perm[:s_active]].set(True)
    coef = jax.random.normal(keys[2], (m, d)) * support[None, :]
    noise = 0.1 * jax.random.normal(keys[3], (m, n))
    targets = jnp.einsum("tnd,td->tn", Xs, coef) + noise
    return ProbeData(features=feats, targets=targets), support
