"""DSML as a framework feature: shared-support multi-task probes."""
from repro.multitask.sparse_probe import (
    ProbeData,
    pool_features,
    probe_predict,
    sparse_probe_fit,
    synthetic_probe_tasks,
)

__all__ = ["ProbeData", "pool_features", "probe_predict",
           "sparse_probe_fit", "synthetic_probe_tasks"]
