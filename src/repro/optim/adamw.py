"""AdamW with ZeRO-1 style master weights, global-norm clipping and a
warmup-cosine schedule (pure JAX).

The model parameters are stored in the compute dtype (bf16) and — under
the production mesh — replicated over the `data` axis; the f32 master
copy and both moments live in AdamWState and are SHARDED over `data`
(ZeRO-1). GSPMD turns the update into: dynamic-slice the (replicated)
gradient -> sharded moment/master update -> all-gather of the new bf16
parameters. See repro.sharding.rules.opt_pspecs.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    master: dict            # float32 master weights (ZeRO-sharded)
    mu: dict
    nu: dict
    count: jnp.ndarray


def warmup_cosine(step, *, peak_lr: float, warmup: int, total: int,
                  floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * (step + 1.0) / jnp.maximum(warmup, 1)
    frac = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def adamw_init(params) -> AdamWState:
    # jnp.array(copy=True): an f32 param must NOT alias its master copy
    # (donating both to the train step would donate one buffer twice)
    f32 = lambda p: jax.tree.map(
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), p)
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return AdamWState(master=f32(params), mu=zeros(params), nu=zeros(params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, clip_norm=1.0,
                 grads_pspec=None):
    """Returns (new_params, new_state, metrics). `params` supplies the
    output dtype; all arithmetic runs on the f32 master copy.
    `grads_pspec` (ZeRO specs) keeps the f32 gradient intermediates
    sharded over `data` instead of at the forward (replicated) layout."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    if grads_pspec is not None:
        grads = jax.lax.with_sharding_constraint(grads, grads_pspec)

    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(w, m, v):
        step = lr * (m / c1) / (jnp.sqrt(v / c2) + eps)
        return w - step - lr * weight_decay * w

    master = jax.tree.map(upd, state.master, mu, nu)
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), master, params)
    return new_params, AdamWState(master, mu, nu, count), {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}
