"""The "dirty model" baseline (Jalali et al., 2010), used by the paper's
real-data comparison: B = S + E with S row-sparse (shared support,
l1/linf penalty) and E elementwise-sparse (task-private deviations).

    min (1/(mn)) sum_t ||y_t - X_t (s_t + e_t)||^2
        + lam_s * sum_j max_t |S_tj| + lam_e * ||E||_1

Solved by proximal BLOCK-coordinate descent: alternate FISTA-style
proximal gradient steps on S (row-linf prox) and E (soft threshold).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.prox import prox_linf, soft_threshold
from repro.core.solvers import power_iteration


@partial(jax.jit, static_argnames=("iters",))
def dirty_model(Xs: jnp.ndarray, ys: jnp.ndarray, lam_s, lam_e,
                iters: int = 400):
    """Xs: (m, n, p); ys: (m, n). Returns (B, S, E), each (p, m)."""
    m, n, p = Xs.shape
    Sigmas = jnp.einsum("tni,tnj->tij", Xs, Xs) / n
    cs = jnp.einsum("tni,tn->ti", Xs, ys) / n
    L = 2.0 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):  # B: (p, m)
        return (2.0 / m) * (jnp.einsum("tij,jt->it", Sigmas, B) - cs.T)

    def body(_, carry):
        S, E = carry
        g = grad(S + E)
        S = prox_linf(S - step * g, step * lam_s)
        g = grad(S + E)
        E = soft_threshold(E - step * g, step * lam_e)
        return S, E

    S0 = jnp.zeros((p, m), Xs.dtype)
    S, E = jax.lax.fori_loop(0, iters, body, (S0, S0))
    return S + E, S, E
