"""DSML — Distributed debiased Sparse Multi-task Lasso (paper Algorithm 1).

Two implementations of the same algorithm:

  * `dsml_fit`          — single-host reference (vmap over tasks).
  * `dsml_fit_sharded`  — SPMD implementation with `shard_map` over a
    1-D "task" mesh axis. Each device plays the role of one worker
    (or a group of workers); the ONLY communication is a single
    `all_gather` of the debiased p-vector per worker — O(p) per device,
    exactly the paper's one round. The master's group-hard-threshold is
    computed replicated (identical on every device), which on a TPU mesh
    is equivalent to (and cheaper than) master + broadcast.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax import shard_map

from repro.core.debias import debias_lasso
from repro.core.prox import support_from_rows
from repro.core.solvers import lasso, refit_ols_masked


class DsmlResult(NamedTuple):
    beta_tilde: jnp.ndarray   # (m, p) final filtered estimates
    beta_u: jnp.ndarray       # (m, p) debiased estimates (communicated)
    support: jnp.ndarray      # (p,) bool, \hat S(Lambda)
    beta_local: jnp.ndarray   # (m, p) local lasso estimates (step 1)


def _local_work(X, y, lam, mu, lasso_iters, debias_iters):
    """Steps 1-2 of Algorithm 1: local lasso + debiasing. No communication."""
    beta_hat = lasso(X, y, lam, iters=lasso_iters)
    beta_u = debias_lasso(X, y, beta_hat, mu, iters=debias_iters)
    return beta_hat, beta_u


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters", "refit"))
def dsml_fit(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam,
    mu,
    Lam,
    lasso_iters: int = 400,
    debias_iters: int = 600,
    refit: bool = False,
) -> DsmlResult:
    """Single-host reference. Xs: (m, n, p), ys: (m, n)."""
    beta_hat, beta_u = jax.vmap(
        lambda X, y: _local_work(X, y, lam, mu, lasso_iters, debias_iters)
    )(Xs, ys)
    support = support_from_rows(beta_u.T, Lam)            # master: eq. (5)
    if refit:
        beta_tilde = jax.vmap(lambda X, y: refit_ols_masked(X, y, support))(Xs, ys)
    else:
        beta_tilde = beta_u * support[None, :]            # workers: eq. (6)
    return DsmlResult(beta_tilde, beta_u, support, beta_hat)


def dsml_fit_sharded(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam,
    mu,
    Lam,
    mesh: Mesh,
    axis: str = "task",
    lasso_iters: int = 400,
    debias_iters: int = 600,
) -> DsmlResult:
    """SPMD DSML over `mesh[axis]` devices. Xs: (m, n, p) sharded on axis 0.

    Communication: exactly one `all_gather` of (m_local, p) debiased
    estimates per device — O(p) numbers per worker, the paper's budget.
    """

    def worker(X_blk, y_blk):
        # X_blk: (m_local, n, p) — the tasks owned by this device.
        beta_hat, beta_u = jax.vmap(
            lambda X, y: _local_work(X, y, lam, mu, lasso_iters, debias_iters)
        )(X_blk, y_blk)
        # ---- the ONE communication round of Algorithm 1 ----
        B_all = jax.lax.all_gather(beta_u, axis, tiled=True)   # (m, p) everywhere
        # ---- master step, replicated (== master + broadcast) ----
        support = support_from_rows(B_all.T, Lam)
        beta_tilde = beta_u * support[None, :]
        return beta_tilde, beta_u, support, beta_hat

    fn = shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(), P(axis)),
        check_vma=False,
    )
    beta_tilde, beta_u, support, beta_hat = jax.jit(fn)(Xs, ys)
    return DsmlResult(beta_tilde, beta_u, support, beta_hat)
