"""DSML — Distributed debiased Sparse Multi-task Lasso (paper Algorithm 1).

Two implementations of the same algorithm:

  * `dsml_fit`          — single-host reference.
  * `dsml_fit_sharded`  — SPMD implementation with `shard_map` over a
    1-D "task" mesh axis (resolved portably via `repro.substrate`).
    Each device plays the role of one worker (or a group of workers);
    the ONLY communication is a single `all_gather` of the debiased
    p-vector per worker — O(p) per device, exactly the paper's one
    round. The master's group-hard-threshold is computed replicated
    (identical on every device), which on a TPU mesh is equivalent to
    (and cheaper than) master + broadcast.

Both run steps 1-2 through the batched sufficient-statistics engine
(core/engine.py): the m local lassos are ONE batched solve, and the m
debias M-matrix estimations are ONE batched multi-RHS solve — the hot
loop is the fused Pallas `ista_step_batched` kernel on TPU and a single
XLA batched matmul elsewhere, instead of a vmap of per-task scalar
FISTA loops.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.engine import (
    debias_batched,
    inverse_hessian_batched,
    power_iteration_batched,
    solve_lasso_eq2,
    sufficient_stats,
)
from repro.core.prox import support_from_rows
from repro.core.solvers import refit_ols_masked_stats
from repro.substrate import all_gather_tasks, shard_map


class DsmlResult(NamedTuple):
    beta_tilde: jnp.ndarray   # (m, p) final filtered estimates
    beta_u: jnp.ndarray       # (m, p) debiased estimates (communicated)
    support: jnp.ndarray      # (p,) bool, \hat S(Lambda)
    beta_local: jnp.ndarray   # (m, p) local lasso estimates (step 1)


def _local_work_stats(Sigmas, cs, lam, mu, lasso_iters, debias_iters):
    """Steps 1-2 of Algorithm 1 on sufficient statistics, batched over
    the m local tasks. No communication. One shared power iteration
    feeds both solves' step sizes."""
    lam_max = power_iteration_batched(Sigmas)
    beta_hat = solve_lasso_eq2(Sigmas, cs, lam, iters=lasso_iters,
                               lam_max=lam_max)
    Ms = inverse_hessian_batched(Sigmas, mu, iters=debias_iters,
                                 lam_max=lam_max)
    beta_u = debias_batched(Sigmas, cs, beta_hat, Ms)
    return beta_hat, beta_u


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters", "refit"))
def dsml_fit(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam,
    mu,
    Lam,
    lasso_iters: int = 400,
    debias_iters: int = 600,
    refit: bool = False,
) -> DsmlResult:
    """Single-host reference. Xs: (m, n, p), ys: (m, n)."""
    Sigmas, cs = sufficient_stats(Xs, ys)
    beta_hat, beta_u = _local_work_stats(Sigmas, cs, lam, mu,
                                         lasso_iters, debias_iters)
    support = support_from_rows(beta_u.T, Lam)            # master: eq. (5)
    if refit:
        beta_tilde = jax.vmap(
            lambda S, c: refit_ols_masked_stats(S, c, support))(Sigmas, cs)
    else:
        beta_tilde = beta_u * support[None, :]            # workers: eq. (6)
    return DsmlResult(beta_tilde, beta_u, support, beta_hat)


def dsml_sharded_fn(
    lam,
    mu,
    Lam,
    mesh: Mesh,
    axis: str = "task",
    lasso_iters: int = 400,
    debias_iters: int = 600,
):
    """The shard-mapped SPMD worker as a callable (Xs, ys) -> DsmlResult
    fields. Exposed separately from `dsml_fit_sharded` so probes can
    `jax.jit(...).lower(...)` the ACTUAL implementation and inspect its
    collectives."""

    def worker(X_blk, y_blk):
        # X_blk: (m_local, n, p) — the tasks owned by this device.
        Sigmas, cs = sufficient_stats(X_blk, y_blk)
        beta_hat, beta_u = _local_work_stats(Sigmas, cs, lam, mu,
                                             lasso_iters, debias_iters)
        # ---- the ONE communication round of Algorithm 1 ----
        B_all = all_gather_tasks(beta_u, axis)             # (m, p) everywhere
        # ---- master step, replicated (== master + broadcast) ----
        support = support_from_rows(B_all.T, Lam)
        beta_tilde = beta_u * support[None, :]
        return beta_tilde, beta_u, support, beta_hat

    return shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(axis), P(axis), P(), P(axis)),
    )


def dsml_fit_sharded(
    Xs: jnp.ndarray,
    ys: jnp.ndarray,
    lam,
    mu,
    Lam,
    mesh: Mesh,
    axis: str = "task",
    lasso_iters: int = 400,
    debias_iters: int = 600,
) -> DsmlResult:
    """SPMD DSML over `mesh[axis]` devices. Xs: (m, n, p) sharded on axis 0.

    Communication: exactly one `all_gather` of (m_local, p) debiased
    estimates per device — O(p) numbers per worker, the paper's budget.
    """
    fn = dsml_sharded_fn(lam, mu, Lam, mesh, axis=axis,
                         lasso_iters=lasso_iters, debias_iters=debias_iters)
    beta_tilde, beta_u, support, beta_hat = jax.jit(fn)(Xs, ys)
    return DsmlResult(beta_tilde, beta_u, support, beta_hat)
