"""FISTA solvers for the local lasso, group lasso and iCAP estimators.

The paper's objectives:

  lasso (eq. 2):        (1/n)||y_t - X_t b||^2 + lambda_t ||b||_1
  multi-task (eq. 3):   (1/(mn)) sum_t ||y_t - X_t b_t||^2 + lambda*pen(B)
      pen = sum_j ||B_j||_2      (group lasso)
      pen = sum_j max_t |B_tj|   (iCAP)

All solvers use FISTA with a fixed iteration budget so they jit cleanly
(`jax.lax.fori_loop`), with the Lipschitz constant obtained from power
iteration on the empirical covariance.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.prox import group_soft_threshold, prox_linf


class FistaResult(NamedTuple):
    beta: jnp.ndarray
    objective: jnp.ndarray
    steps: jnp.ndarray


def power_iteration(S: jnp.ndarray, iters: int = 64) -> jnp.ndarray:
    """Largest eigenvalue of a PSD matrix S (p x p) via power iteration."""
    p = S.shape[-1]
    v = jnp.full((p,), 1.0 / jnp.sqrt(p), dtype=S.dtype)

    def body(_, v):
        w = S @ v
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    return v @ (S @ v)


def fista(grad_fn, prox_fn, x0: jnp.ndarray, step, iters: int) -> jnp.ndarray:
    """Generic FISTA: min f(x) + g(x), grad_fn = grad f, prox_fn(v, step)."""

    def body(_, carry):
        x, z, t = carry
        x_next = prox_fn(z - step * grad_fn(z), step)
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        z_next = x_next + ((t - 1.0) / t_next) * (x_next - x)
        return x_next, z_next, t_next

    x, _, _ = jax.lax.fori_loop(0, iters, body, (x0, x0, jnp.array(1.0, x0.dtype)))
    return x


def lasso_stats_step_scale(Sigma: jnp.ndarray):
    """Step size for the eq.-2 lasso in the engine's normalized gradient
    convention g = Sigma b - c. The objective's gradient is 2(Sigma b - c)
    with Lipschitz constant 2*lambda_max, so the engine step is
    2 * 1/max(2*lambda_max, eps) and the engine threshold weight is
    lam/2 (eta * lam/2 == step * lam of the unnormalized iteration)."""
    L = 2.0 * power_iteration(Sigma)
    return 2.0 / jnp.maximum(L, 1e-12)


@partial(jax.jit, static_argnames=("iters",))
def lasso(X: jnp.ndarray, y: jnp.ndarray, lam, iters: int = 400) -> jnp.ndarray:
    """Local lasso (paper eq. 2). X: (n, p), y: (n,). Returns (p,).

    Thin wrapper over the batched sufficient-statistics engine
    (`core/engine.solve_lasso_eq2`) with batch size 1; reproduces the
    historical FISTA iterates exactly.
    """
    from repro.core.engine import solve_lasso_eq2
    n = X.shape[0]
    Sigma = (X.T @ X) / n                       # empirical covariance
    c = (X.T @ y) / n
    return solve_lasso_eq2(Sigma[None], c[None], lam, iters=iters)[0]


@partial(jax.jit, static_argnames=("iters",))
def group_lasso(Xs: jnp.ndarray, ys: jnp.ndarray, lam, iters: int = 400) -> jnp.ndarray:
    """Centralized multi-task group lasso (eq. 3 with l1/l2 penalty).

    Xs: (m, n, p), ys: (m, n). Returns B: (p, m) (rows = variables).
    """
    from repro.core.engine import sufficient_stats
    m, n, p = Xs.shape
    Sigmas, cs = sufficient_stats(Xs, ys)                    # (m,p,p), (m,p)
    L = 2.0 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):  # B: (p, m); loss (1/(mn)) sum_t ||y_t - X_t b_t||^2
        return (2.0 / m) * (jnp.einsum("tij,jt->it", Sigmas, B) - cs.T)

    prox = lambda V, s: group_soft_threshold(V, s * lam)
    return fista(grad, prox, jnp.zeros((p, m), Xs.dtype), step, iters)


@partial(jax.jit, static_argnames=("iters",))
def icap(Xs: jnp.ndarray, ys: jnp.ndarray, lam, iters: int = 400) -> jnp.ndarray:
    """iCAP estimator: l1/linf composite penalty (Zhao et al., 2009)."""
    from repro.core.engine import sufficient_stats
    m, n, p = Xs.shape
    Sigmas, cs = sufficient_stats(Xs, ys)
    L = 2.0 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):
        return (2.0 / m) * (jnp.einsum("tij,jt->it", Sigmas, B) - cs.T)

    prox = lambda V, s: prox_linf(V, s * lam)
    return fista(grad, prox, jnp.zeros((p, m), Xs.dtype), step, iters)


@jax.jit
def refit_ols_masked_stats(S: jnp.ndarray, c: jnp.ndarray,
                           support: jnp.ndarray) -> jnp.ndarray:
    """OLS refit on sufficient statistics (S = X'X/n, c = X'y/n),
    restricted to `support` (bool (p,)), jit-safe via masking.

    Solves the masked normal equations:
        (D S D + (I - D)) b = D c,   D = diag(support)
    which equals OLS on the support columns and 0 elsewhere.
    """
    p = S.shape[-1]
    d = support.astype(S.dtype)
    A = d[:, None] * S * d[None, :] + jnp.diag(1.0 - d)
    A = A + 1e-8 * jnp.eye(p, dtype=S.dtype)
    return jnp.linalg.solve(A, d * c)


@jax.jit
def refit_ols_masked(X: jnp.ndarray, y: jnp.ndarray, support: jnp.ndarray) -> jnp.ndarray:
    """OLS refit restricted to `support` from raw samples."""
    n = X.shape[0]
    return refit_ols_masked_stats((X.T @ X) / n, (X.T @ y) / n, support)
