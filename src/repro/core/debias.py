"""Debiased lasso (Javanmard-Montanari style) used by DSML step 2.

The paper (Section 4) constructs M_t row-wise:

    m_tj = argmin m^T Sigma_hat m   s.t.  ||Sigma_hat m - e_j||_inf <= mu

On TPU we solve the *penalized* equivalent for all p rows simultaneously
(one matrix FISTA on the MXU instead of p constrained QPs):

    M = argmin_M  (1/2) tr(M Sigma_hat M^T) - tr(M) + mu ||M||_1

whose KKT conditions give  ||Sigma_hat m_j - e_j||_inf <= mu  at any
optimum with active l1 subgradient — i.e. a feasible point of the paper's
program (see DESIGN.md §2, "Debias M-matrix on the MXU", for the
hardware-adaptation note). The identity fallback of Javanmard-Montanari
(Sigma^-1 feasible) carries over.

Both entry points are batch-1 wrappers over the batched
sufficient-statistics engine (core/engine.py): the M columns solve
min 1/2 c' Sigma c - c_j + mu|c|_1, i.e. a p-RHS lasso with c = I.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.engine import (
    debias_batched, inverse_hessian_batched, sufficient_stats,
)


@partial(jax.jit, static_argnames=("iters",))
def inverse_hessian_m(Sigma: jnp.ndarray, mu, iters: int = 600) -> jnp.ndarray:
    """Approximate inverse M (p x p, row j ~= m_tj) of a PSD covariance."""
    return inverse_hessian_batched(Sigma[None], mu, iters=iters)[0]


@partial(jax.jit, static_argnames=("iters",))
def debias_lasso(
    X: jnp.ndarray,
    y: jnp.ndarray,
    beta_hat: jnp.ndarray,
    mu,
    iters: int = 600,
) -> jnp.ndarray:
    """Debiased estimator (paper eq. 4): b^u = b + n^-1 M X^T (y - X b)."""
    Sigmas, cs = sufficient_stats(X[None], y[None])
    M = inverse_hessian_batched(Sigmas, mu, iters=iters)
    return debias_batched(Sigmas, cs, beta_hat[None], M)[0]


def coherence(Sigma: jnp.ndarray, M: jnp.ndarray) -> jnp.ndarray:
    """Generalized coherence mu(X, M) = max_j ||Sigma m_j - e_j||_inf."""
    p = Sigma.shape[0]
    R = M @ Sigma - jnp.eye(p, dtype=Sigma.dtype)
    return jnp.max(jnp.abs(R))
