"""Debiased lasso (Javanmard-Montanari style) used by DSML step 2.

The paper (Section 4) constructs M_t row-wise:

    m_tj = argmin m^T Sigma_hat m   s.t.  ||Sigma_hat m - e_j||_inf <= mu

On TPU we solve the *penalized* equivalent for all p rows simultaneously
(one matrix FISTA on the MXU instead of p constrained QPs):

    M = argmin_M  (1/2) tr(M Sigma_hat M^T) - tr(M) + mu ||M||_1

whose KKT conditions give  ||Sigma_hat m_j - e_j||_inf <= mu  at any
optimum with active l1 subgradient — i.e. a feasible point of the paper's
program (see DESIGN.md §2 for the hardware-adaptation note). The identity
fallback of Javanmard-Montanari (Sigma^-1 feasible) carries over.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.prox import soft_threshold
from repro.core.solvers import fista, power_iteration


@partial(jax.jit, static_argnames=("iters",))
def inverse_hessian_m(Sigma: jnp.ndarray, mu, iters: int = 600) -> jnp.ndarray:
    """Approximate inverse M (p x p, row j ~= m_tj) of a PSD covariance."""
    p = Sigma.shape[0]
    L = power_iteration(Sigma)
    step = 1.0 / jnp.maximum(L, 1e-12)

    # Columns solve  min 1/2 c^T Sigma c - c_j + mu|c|_1 ; Sigma symmetric,
    # so M = C^T has rows m_j. Warm-start from a scaled identity.
    C0 = jnp.eye(p, dtype=Sigma.dtype) / jnp.maximum(jnp.diag(Sigma), 1e-12)
    grad = lambda C: Sigma @ C - jnp.eye(p, dtype=Sigma.dtype)
    prox = lambda V, s: soft_threshold(V, s * mu)
    C = fista(grad, prox, C0, step, iters)
    return C.T


@partial(jax.jit, static_argnames=("iters",))
def debias_lasso(
    X: jnp.ndarray,
    y: jnp.ndarray,
    beta_hat: jnp.ndarray,
    mu,
    iters: int = 600,
) -> jnp.ndarray:
    """Debiased estimator (paper eq. 4): b^u = b + n^-1 M X^T (y - X b)."""
    n = X.shape[0]
    Sigma = (X.T @ X) / n
    M = inverse_hessian_m(Sigma, mu, iters=iters)
    resid = y - X @ beta_hat
    return beta_hat + (M @ (X.T @ resid)) / n


def coherence(Sigma: jnp.ndarray, M: jnp.ndarray) -> jnp.ndarray:
    """Generalized coherence mu(X, M) = max_j ||Sigma m_j - e_j||_inf."""
    p = Sigma.shape[0]
    R = M @ Sigma - jnp.eye(p, dtype=Sigma.dtype)
    return jnp.max(jnp.abs(R))
