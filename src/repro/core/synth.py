"""Synthetic data generation matching the paper's Section 6 setup.

Rows of X_t ~ N(0, Sigma) with Sigma_ab = 2^{-|a-b|}; p = 200, s = 10;
nonzero coefficients uniform in [0, 1]; sigma^2 = 1; shared support.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class MultiTaskData(NamedTuple):
    Xs: jnp.ndarray        # (m, n, p)
    ys: jnp.ndarray        # (m, n)
    B: jnp.ndarray         # (p, m) true coefficients (rows = variables)
    support: jnp.ndarray   # (p,) bool
    Sigma: jnp.ndarray     # (p, p) population covariance


def ar_covariance(p: int, rho: float = 0.5, dtype=jnp.float32) -> jnp.ndarray:
    """Sigma_ab = rho^{|a-b|}; the paper uses 2^{-|a-b|} i.e. rho = 0.5."""
    idx = jnp.arange(p)
    return (rho ** jnp.abs(idx[:, None] - idx[None, :])).astype(dtype)


def sample_coefficients(key, p: int, m: int, s: int, low=0.0, high=1.0,
                        signed: bool = False) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Shared-support coefficient matrix B (p, m)."""
    k_sup, k_val, k_sign = jax.random.split(key, 3)
    perm = jax.random.permutation(k_sup, p)
    support = jnp.zeros(p, bool).at[perm[:s]].set(True)
    vals = jax.random.uniform(k_val, (p, m), minval=low, maxval=high)
    if signed:
        vals = vals * jax.random.choice(k_sign, jnp.array([-1.0, 1.0]), (p, m))
    return vals * support[:, None], support


def gen_regression(key, *, m: int = 10, n: int = 50, p: int = 200, s: int = 10,
                   sigma: float = 1.0, rho: float = 0.5,
                   signal_low: float = 0.0, signal_high: float = 1.0) -> MultiTaskData:
    """Multi-task linear regression data, paper model (1)/(16)."""
    k_b, k_x, k_e = jax.random.split(key, 3)
    Sigma = ar_covariance(p, rho)
    chol = jnp.linalg.cholesky(Sigma + 1e-9 * jnp.eye(p))
    B, support = sample_coefficients(k_b, p, m, s, signal_low, signal_high)
    Z = jax.random.normal(k_x, (m, n, p))
    Xs = Z @ chol.T
    eps = sigma * jax.random.normal(k_e, (m, n))
    ys = jnp.einsum("tnp,pt->tn", Xs, B) + eps
    return MultiTaskData(Xs, ys, B, support, Sigma)


def gen_classification(key, *, m: int = 10, n: int = 150, p: int = 200, s: int = 10,
                       rho: float = 0.5, signal_scale: float = 2.0) -> MultiTaskData:
    """Multi-task logistic data, paper model (7): y in {-1, +1},
    P(y|x) = sigmoid(y * x @ beta)."""
    k_b, k_x, k_y = jax.random.split(key, 3)
    Sigma = ar_covariance(p, rho)
    chol = jnp.linalg.cholesky(Sigma + 1e-9 * jnp.eye(p))
    B, support = sample_coefficients(k_b, p, m, s, 0.0, signal_scale)
    Z = jax.random.normal(k_x, (m, n, p))
    Xs = Z @ chol.T
    logits = jnp.einsum("tnp,pt->tn", Xs, B)
    u = jax.random.uniform(k_y, (m, n))
    ys = jnp.where(u < jax.nn.sigmoid(logits), 1.0, -1.0)
    return MultiTaskData(Xs, ys, B, support, Sigma)
