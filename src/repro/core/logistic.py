"""Multi-task classification extension of DSML (paper Section 4).

Model (paper eq. 7): y in {-1, +1}, P(y|x) = sigmoid(y * x @ beta).

  1. local l1-regularized logistic regression (FISTA),
  2. debiasing with the weighted Hessian  n^-1 X^T W X,
     W_kk = sigmoid(x_k b) * sigmoid(-x_k b),
  3. the same one-round group hard-thresholding at the master.

Engine v2: every solver here is a thin wrapper over
`core/engine.solve_logistic_lasso_batched` — one batched FISTA loop
whose gradient is a single all-tasks einsum — instead of per-task
`vmap(fista)` loops. `dsml_logistic_fit` also batches step 2: the m
weighted Hessians come from one `sufficient_stats(weights=...)` call
and the m M-estimations are one multi-RHS `inverse_hessian_batched`
solve (DESIGN.md §10).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.engine import (
    inverse_hessian_batched,
    power_iteration_batched,
    scaled_identity_m0,
    solve_logistic_lasso_batched,
    sufficient_stats,
)
from repro.core.prox import support_from_rows


@partial(jax.jit, static_argnames=("iters",))
def logistic_lasso(X: jnp.ndarray, y: jnp.ndarray, lam, iters: int = 600) -> jnp.ndarray:
    """l1-regularized logistic regression. X: (n,p), y: (n,) in {-1,+1}.

    Batch-1 wrapper over the batched engine loop (the covariance behind
    the Lipschitz bound comes from `sufficient_stats`).
    """
    return solve_logistic_lasso_batched(X[None], y[None], lam,
                                        iters=iters)[0]


@partial(jax.jit, static_argnames=("iters",))
def debias_logistic_batched(Xs: jnp.ndarray, ys: jnp.ndarray,
                            beta_hat: jnp.ndarray, mu, iters: int = 600,
                            M0: jnp.ndarray | None = None,
                            M0_valid: jnp.ndarray | None = None):
    """Weighted-Hessian debias (paper Section 4) for all m tasks at
    once — THE logistic step-2 code path, shared by `debias_logistic`,
    `dsml_logistic_fit`, and the streaming `refit_logistic`.

    One weighted `sufficient_stats` builds the m Hessians
    n^-1 X'WX (W_kk = sigma(x_k b) sigma(-x_k b)), one multi-RHS
    `inverse_hessian_batched` estimates all Ms, and one batched score
    correction b + M X'(1/2(y+1) - sigma(Xb))/n debias all tasks.
    `M0` (m, p, p) warm-starts the M solve; the traced bool `M0_valid`
    gates it per call (a streaming generation-0 refit falls back to the
    scaled-identity start). Returns (beta_u, Ms).
    """
    n = Xs.shape[1]
    zs = jnp.einsum("tnp,tp->tn", Xs, beta_hat)
    ws = jax.nn.sigmoid(zs) * jax.nn.sigmoid(-zs)            # W_kk
    Sigma_w, _ = sufficient_stats(Xs, ys, weights=ws)
    if M0 is not None and M0_valid is not None:
        M0 = jnp.where(M0_valid, M0, scaled_identity_m0(Sigma_w))
    Ms = inverse_hessian_batched(Sigma_w, mu, iters=iters, M0=M0)
    score = (0.5 * (ys + 1.0)) - jax.nn.sigmoid(zs)          # 1/2(y+1) - sigma(Xb)
    beta_u = beta_hat + jnp.einsum(
        "tij,tj->ti", Ms, jnp.einsum("tnp,tn->tp", Xs, score)) / n
    return beta_u, Ms


@partial(jax.jit, static_argnames=("iters",))
def debias_logistic(X: jnp.ndarray, y: jnp.ndarray, beta_hat: jnp.ndarray,
                    mu, iters: int = 600) -> jnp.ndarray:
    """Debiased l1-logistic estimator (paper Section 4, classification).
    Batch-1 wrapper over `debias_logistic_batched`."""
    beta_u, _ = debias_logistic_batched(X[None], y[None], beta_hat[None],
                                        mu, iters=iters)
    return beta_u[0]


class DsmlLogisticResult(NamedTuple):
    beta_tilde: jnp.ndarray
    beta_u: jnp.ndarray
    support: jnp.ndarray
    beta_local: jnp.ndarray


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters"))
def dsml_logistic_fit(Xs: jnp.ndarray, ys: jnp.ndarray, lam, mu, Lam,
                      lasso_iters: int = 600, debias_iters: int = 600) -> DsmlLogisticResult:
    """DSML for multi-task classification. Xs: (m,n,p), ys: (m,n).

    Steps 1-2 are each ONE batched engine call: the m local l1-logistic
    solves share a single FISTA loop, and the m weighted-Hessian
    M-estimations share a single multi-RHS lasso solve.
    """
    beta_hat = solve_logistic_lasso_batched(Xs, ys, lam, iters=lasso_iters)
    beta_u, _ = debias_logistic_batched(Xs, ys, beta_hat, mu,
                                        iters=debias_iters)
    support = support_from_rows(beta_u.T, Lam)
    beta_tilde = beta_u * support[None, :]
    return DsmlLogisticResult(beta_tilde, beta_u, support, beta_hat)


@partial(jax.jit, static_argnames=("iters",))
def group_logistic_lasso(Xs: jnp.ndarray, ys: jnp.ndarray, lam,
                         iters: int = 600) -> jnp.ndarray:
    """Centralized multi-task group-lasso logistic baseline. Returns (p, m).

    The engine loop with a shared step size (the 1/(mn) objective's
    Lipschitz bound), the gradient scaled by 1/m, and the row-coupled
    group soft threshold as the prox.
    """
    from repro.core.prox import group_soft_threshold
    m, n, p = Xs.shape
    Sigmas, _ = sufficient_stats(Xs, ys)
    L = 0.25 / m * jnp.max(power_iteration_batched(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)
    prox = lambda V, steps: group_soft_threshold(V.T, steps[0, 0] * lam).T
    B = solve_logistic_lasso_batched(Xs, ys, lam, iters=iters,
                                     etas=jnp.full((m,), step, Xs.dtype),
                                     grad_scale=1.0 / m, prox=prox)
    return B.T


@partial(jax.jit, static_argnames=("iters",))
def icap_logistic(Xs: jnp.ndarray, ys: jnp.ndarray, lam, iters: int = 600) -> jnp.ndarray:
    """iCAP (l1/linf) multi-task logistic baseline. Returns (p, m)."""
    from repro.core.prox import prox_linf
    m, n, p = Xs.shape
    Sigmas, _ = sufficient_stats(Xs, ys)
    L = 0.25 / m * jnp.max(power_iteration_batched(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)
    prox = lambda V, steps: prox_linf(V.T, steps[0, 0] * lam).T
    B = solve_logistic_lasso_batched(Xs, ys, lam, iters=iters,
                                     etas=jnp.full((m,), step, Xs.dtype),
                                     grad_scale=1.0 / m, prox=prox)
    return B.T


@partial(jax.jit, static_argnames=("steps",))
def refit_logistic_masked(X: jnp.ndarray, y: jnp.ndarray, support: jnp.ndarray,
                          steps: int = 200) -> jnp.ndarray:
    """Newton-free masked logistic refit via gradient descent on the support.

    The engine loop with `momentum=False` (plain proximal gradient) and
    the support mask as the prox — identical iterates to the historical
    hand-rolled GD loop, with the Lipschitz covariance deduped through
    `sufficient_stats`.
    """
    d = support.astype(X.dtype)
    prox = lambda V, _: V * d[None, :]
    return solve_logistic_lasso_batched(X[None], y[None], 0.0, iters=steps,
                                        momentum=False, prox=prox)[0]
