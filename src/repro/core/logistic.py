"""Multi-task classification extension of DSML (paper Section 4).

Model (paper eq. 7): y in {-1, +1}, P(y|x) = sigmoid(y * x @ beta).

  1. local l1-regularized logistic regression (FISTA),
  2. debiasing with the weighted Hessian  n^-1 X^T W X,
     W_kk = sigmoid(x_k b) * sigmoid(-x_k b),
  3. the same one-round group hard-thresholding at the master.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.debias import inverse_hessian_m
from repro.core.engine import sufficient_stats
from repro.core.prox import soft_threshold, support_from_rows
from repro.core.solvers import fista, power_iteration, refit_ols_masked


@partial(jax.jit, static_argnames=("iters",))
def logistic_lasso(X: jnp.ndarray, y: jnp.ndarray, lam, iters: int = 600) -> jnp.ndarray:
    """l1-regularized logistic regression. X: (n,p), y: (n,) in {-1,+1}."""
    n = X.shape[0]
    Sigma = (X.T @ X) / n
    # Hessian of the logistic loss is bounded by Sigma/4.
    L = 0.25 * power_iteration(Sigma)
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(b):
        z = X @ b
        return -(X.T @ (y * jax.nn.sigmoid(-y * z))) / n

    prox = lambda v, s: soft_threshold(v, s * lam)
    return fista(grad, prox, jnp.zeros(X.shape[1], X.dtype), step, iters)


@partial(jax.jit, static_argnames=("iters",))
def debias_logistic(X: jnp.ndarray, y: jnp.ndarray, beta_hat: jnp.ndarray,
                    mu, iters: int = 600) -> jnp.ndarray:
    """Debiased l1-logistic estimator (paper Section 4, classification)."""
    n = X.shape[0]
    z = X @ beta_hat
    w = jax.nn.sigmoid(z) * jax.nn.sigmoid(-z)               # W_kk
    Sigma_w, _ = sufficient_stats(X[None], y[None], weights=w[None])
    M = inverse_hessian_m(Sigma_w[0], mu, iters=iters)       # n^-1 X^T W X
    score = (0.5 * (y + 1.0)) - jax.nn.sigmoid(z)            # 1/2(y+1) - sigma(Xb)
    return beta_hat + (M @ (X.T @ score)) / n


class DsmlLogisticResult(NamedTuple):
    beta_tilde: jnp.ndarray
    beta_u: jnp.ndarray
    support: jnp.ndarray
    beta_local: jnp.ndarray


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters"))
def dsml_logistic_fit(Xs: jnp.ndarray, ys: jnp.ndarray, lam, mu, Lam,
                      lasso_iters: int = 600, debias_iters: int = 600) -> DsmlLogisticResult:
    """DSML for multi-task classification. Xs: (m,n,p), ys: (m,n)."""
    beta_hat = jax.vmap(lambda X, y: logistic_lasso(X, y, lam, iters=lasso_iters))(Xs, ys)
    beta_u = jax.vmap(lambda X, y, b: debias_logistic(X, y, b, mu, iters=debias_iters))(
        Xs, ys, beta_hat)
    support = support_from_rows(beta_u.T, Lam)
    beta_tilde = beta_u * support[None, :]
    return DsmlLogisticResult(beta_tilde, beta_u, support, beta_hat)


@partial(jax.jit, static_argnames=("iters",))
def group_logistic_lasso(Xs: jnp.ndarray, ys: jnp.ndarray, lam,
                         iters: int = 600) -> jnp.ndarray:
    """Centralized multi-task group-lasso logistic baseline. Returns (p, m)."""
    from repro.core.prox import group_soft_threshold
    m, n, p = Xs.shape
    Sigmas, _ = sufficient_stats(Xs, ys)
    L = 0.25 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):  # B: (p, m)
        z = jnp.einsum("tnp,pt->tn", Xs, B)
        g = -jnp.einsum("tnp,tn->pt", Xs, ys * jax.nn.sigmoid(-ys * z)) / n
        return g / m

    prox = lambda V, s: group_soft_threshold(V, s * lam)
    return fista(grad, prox, jnp.zeros((p, m), Xs.dtype), step, iters)


@partial(jax.jit, static_argnames=("iters",))
def icap_logistic(Xs: jnp.ndarray, ys: jnp.ndarray, lam, iters: int = 600) -> jnp.ndarray:
    """iCAP (l1/linf) multi-task logistic baseline. Returns (p, m)."""
    from repro.core.prox import prox_linf
    m, n, p = Xs.shape
    Sigmas, _ = sufficient_stats(Xs, ys)
    L = 0.25 / m * jnp.max(jax.vmap(power_iteration)(Sigmas))
    step = 1.0 / jnp.maximum(L, 1e-12)

    def grad(B):
        z = jnp.einsum("tnp,pt->tn", Xs, B)
        g = -jnp.einsum("tnp,tn->pt", Xs, ys * jax.nn.sigmoid(-ys * z)) / n
        return g / m

    prox = lambda V, s: prox_linf(V, s * lam)
    return fista(grad, prox, jnp.zeros((p, m), Xs.dtype), step, iters)


@jax.jit
def refit_logistic_masked(X: jnp.ndarray, y: jnp.ndarray, support: jnp.ndarray,
                          steps: int = 200) -> jnp.ndarray:
    """Newton-free masked logistic refit via gradient descent on the support."""
    n, p = X.shape
    d = support.astype(X.dtype)
    Sigma = (X.T @ X) / n
    L = 0.25 * power_iteration(Sigma)
    step = 1.0 / jnp.maximum(L, 1e-12)

    def body(_, b):
        z = X @ b
        g = -(X.T @ (y * jax.nn.sigmoid(-y * z))) / n
        return (b - step * g) * d

    return jax.lax.fori_loop(0, steps, body, jnp.zeros(p, X.dtype))
