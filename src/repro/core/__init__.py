"""Core DSML library: the paper's contribution as composable JAX modules."""
from repro.core.dirty import dirty_model
from repro.core.dsml import DsmlResult, dsml_fit, dsml_fit_sharded
from repro.core.debias import coherence, debias_lasso, inverse_hessian_m
from repro.core.logistic import (
    debias_logistic,
    debias_logistic_batched,
    dsml_logistic_fit,
    group_logistic_lasso,
    icap_logistic,
    logistic_lasso,
    refit_logistic_masked,
)
from repro.core.metrics import (
    classification_error,
    estimation_error,
    hamming,
    prediction_error,
    support_of,
)
from repro.core.prox import (
    group_hard_threshold,
    group_soft_threshold,
    project_l1_ball,
    prox_linf,
    soft_threshold,
    support_from_rows,
)
from repro.core.engine import (
    debias_batched,
    inverse_hessian_batched,
    power_iteration_batched,
    solve_lasso_batched,
    solve_lasso_eq2,
    solve_lasso_eq2_grid,
    solve_lasso_grid,
    solve_logistic_lasso_batched,
    sufficient_stats,
)
from repro.core.solvers import (
    fista,
    group_lasso,
    icap,
    lasso,
    power_iteration,
    refit_ols_masked,
    refit_ols_masked_stats,
)
from repro.core.synth import (
    MultiTaskData,
    ar_covariance,
    gen_classification,
    gen_regression,
    sample_coefficients,
)

__all__ = [
    "dirty_model",
    "DsmlResult", "dsml_fit", "dsml_fit_sharded",
    "coherence", "debias_lasso", "inverse_hessian_m",
    "debias_logistic", "debias_logistic_batched", "dsml_logistic_fit",
    "group_logistic_lasso",
    "icap_logistic", "logistic_lasso", "refit_logistic_masked",
    "classification_error", "estimation_error", "hamming",
    "prediction_error", "support_of",
    "group_hard_threshold", "group_soft_threshold", "project_l1_ball",
    "prox_linf", "soft_threshold", "support_from_rows",
    "debias_batched", "inverse_hessian_batched", "power_iteration_batched",
    "solve_lasso_batched", "solve_lasso_eq2", "solve_lasso_eq2_grid",
    "solve_lasso_grid", "solve_logistic_lasso_batched", "sufficient_stats",
    "fista", "group_lasso", "icap", "lasso", "power_iteration",
    "refit_ols_masked", "refit_ols_masked_stats",
    "MultiTaskData", "ar_covariance", "gen_classification",
    "gen_regression", "sample_coefficients",
]
