"""Proximal operators used by the DSML solvers.

All operators are pure jnp functions, jit- and vmap-safe, and operate on
arbitrary leading batch dimensions unless noted.
"""
from __future__ import annotations

import jax.numpy as jnp


def soft_threshold(v: jnp.ndarray, tau) -> jnp.ndarray:
    """Elementwise soft-thresholding: prox of tau*||.||_1."""
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - tau, 0.0)


def group_soft_threshold(B: jnp.ndarray, tau) -> jnp.ndarray:
    """Row-wise group soft threshold: prox of tau * sum_j ||B_j||_2.

    B: (p, m) matrix whose rows are groups (variable j across tasks).
    """
    norms = jnp.linalg.norm(B, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - tau / jnp.maximum(norms, 1e-30), 0.0)
    return B * scale


def group_hard_threshold(B: jnp.ndarray, Lam) -> jnp.ndarray:
    """Row-wise hard threshold (paper eq. (5)-(6)). B: (p, m)."""
    keep = jnp.linalg.norm(B, axis=-1, keepdims=True) > Lam
    return B * keep


def support_from_rows(B: jnp.ndarray, Lam) -> jnp.ndarray:
    """\\hat S(Lambda) = { j : ||B_j||_2 > Lambda }. B: (p, m) -> (p,) bool."""
    return jnp.linalg.norm(B, axis=-1) > Lam


def project_l1_ball(v: jnp.ndarray, radius) -> jnp.ndarray:
    """Euclidean projection of a vector v onto the l1 ball of given radius.

    Duchi et al. (2008) sort-based algorithm, jit-safe (no data-dependent
    shapes). v: (..., d) applied along the last axis.
    """
    radius = jnp.asarray(radius, v.dtype)
    abs_v = jnp.abs(v)
    inside = jnp.sum(abs_v, axis=-1, keepdims=True) <= radius
    u = jnp.sort(abs_v, axis=-1)[..., ::-1]
    cssv = jnp.cumsum(u, axis=-1) - radius
    ar = jnp.arange(1, v.shape[-1] + 1, dtype=v.dtype)
    cond = u - cssv / ar > 0
    rho = jnp.sum(cond, axis=-1, keepdims=True)  # >= 1 when outside ball
    rho = jnp.maximum(rho, 1)
    theta = jnp.take_along_axis(cssv, rho - 1, axis=-1) / rho.astype(v.dtype)
    theta = jnp.maximum(theta, 0.0)
    proj = jnp.sign(v) * jnp.maximum(abs_v - theta, 0.0)
    return jnp.where(inside, v, proj)


def prox_linf(v: jnp.ndarray, tau) -> jnp.ndarray:
    """Prox of tau*||.||_inf along the last axis (used by iCAP rows).

    Moreau decomposition: prox_{tau*||.||_inf}(v) = v - P_{tau*B_1}(v).
    """
    return v - project_l1_ball(v, tau)
