"""Batched sufficient-statistics solver engine.

Every l1-regularized quadratic this repo solves — the per-task lasso of
DSML step 1, the debias M-matrix estimation of step 2, the tuned lasso
sweeps of the paper benchmarks — is an instance of

    min_b  (1/2) b' Sigma b - c' b + lam ||b||_1

on precomputed sufficient statistics (Sigma, c). The engine solves a
whole BATCH of such problems (independent Sigmas, multi-RHS c) in one
accelerated FISTA loop whose hot step is the fused Pallas
`ista_step_batched` kernel — one MXU-shaped stream of tiles instead of a
vmap of m scalar solver loops. Off-TPU the step runs as one XLA batched
matmul (the kernel's jnp oracle), so CPU tests stay fast; pass
`use_kernel=True, interpret=True` to exercise the pallas path anywhere.

`core/solvers.lasso`, `core/debias.inverse_hessian_m` and
`core/dsml.dsml_fit{,_sharded}` are thin wrappers over this engine; they
reproduce the original FISTA iterates exactly (same step sizes, same
momentum schedule) because the engine works in the normalized gradient
convention g = Sigma b - c with caller-supplied per-task step sizes.

Engine v2 (DESIGN.md §10): each FISTA iteration is ONE fused kernel
dispatch (`fista_step_batched` computes the prox'd iterate and the
momentum extrapolation in the same epilogue), `tol=` adds
convergence-aware early exit on the prox-gradient KKT residual, the
kernel block policy defaults to the autotuned winner for the shape
(`kernels/autotune.py`; explicit `block=` wins), and
`solve_logistic_lasso_batched` extends the batched loop to the
Section-4 logistic path — every task's l1-logistic solve as one
all-tasks gradient instead of a vmap of per-task FISTA loops.

The sample-streaming hot paths are fused too (DESIGN.md §11): the
logistic gradient runs as the `kernels/logistic_grad` Pallas kernel
(forward matvec, sigmoid residual, and back-projection from the same
resident X tiles) and `sufficient_stats` as the `kernels/rank_update`
kernel (Sigma and c from one pass over the chunk) — both behind the
standard dispatch convention: kernel by default on TPU, bitwise jnp
oracle as the fast CPU path and the ragged-shape fallback, autotuned
default block sizes under their own `kernels/autotune.py` namespaces.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.prox import soft_threshold
from repro.core.solvers import lasso_stats_step_scale, power_iteration
from repro.kernels.ista_step.ops import fista_step_batched
from repro.kernels.ista_step.ref import (
    fista_step_batched_ref, ista_step_batched_ref,
)
from repro.kernels.logistic_grad.ops import logistic_grad, routes_to_oracle
from repro.kernels.logistic_grad.ref import logistic_grad_ref
from repro.kernels.rank_update.ops import rank_routes_to_oracle, rank_update


def power_iteration_batched(Sigmas: jnp.ndarray, iters: int = 64) -> jnp.ndarray:
    """Largest eigenvalue per task of a (m, p, p) PSD stack."""
    return jax.vmap(partial(power_iteration, iters=iters))(Sigmas)


def _trace_clean() -> bool:
    # fail CLOSED when the installed jax no longer exposes the probe:
    # skipping a telemetry record is free, scalarizing a tracer is not
    return bool(getattr(jax.core, "trace_state_clean", lambda: False)())


def _record_solve(kind: str, n_iters, ceiling: int) -> None:
    """Record a solve's iterations-used vs its `iters` ceiling (and the
    early-exit verdict the `tol=`/`return_iters` machinery implies).
    Eager-only by construction: when a caller jits a public wrapper the
    whole wrapper body runs under trace and `int(n_iters)` would
    scalarize a tracer — so this is a no-op unless the trace state is
    clean (RL107 territory; RL108 additionally lint-proves no jit root
    in this module can reach an obs call)."""
    if not obs.enabled() or not _trace_clean():
        return
    used = int(n_iters)
    obs.inc("engine.solve.calls", kind=kind)
    obs.observe("engine.solve.iters_used", used, kind=kind)
    obs.observe("engine.solve.iters_ceiling", ceiling, kind=kind)
    if used < ceiling:
        obs.inc("engine.solve.early_exit", kind=kind)


def sufficient_stats(Xs: jnp.ndarray, ys: jnp.ndarray,
                     weights: jnp.ndarray | None = None, *,
                     use_kernel: bool | None = None,
                     interpret: bool | None = None,
                     block=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-task empirical covariance and correlation.

    Xs: (m, n, p), ys: (m, n) -> Sigmas (m, p, p), cs (m, p). These two
    arrays are ALL the data any downstream solve touches; raw (X, y)
    never re-enters the hot loop.

    `weights` (m, n) are optional per-sample weights, still normalized
    by n: Sigma_w = n^-1 X' W X, c_w = n^-1 X' W y. This is the one code
    path behind both the logistic debias Hessian (W = sigma(z)sigma(-z))
    and the streaming layer's per-sample importance weighting.

    The reduction is the fused rank-n Pallas kernel
    (`kernels/rank_update`: Sigma and c from ONE pass over the sample
    chunk) when `use_kernel` — default only on TPU; the jnp einsum
    oracle is the fast CPU path and the ragged-shape fallback. `block`
    is an int, an explicit (bp, bn) pair, or None for the autotuned
    per-shape policy (DESIGN.md §11).
    """
    m, n, p = Xs.shape
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    block = resolve_rank_block_policy(m, n, p, Xs.dtype, block, use_kernel)
    return rank_update(Xs, ys, weights, use_kernel=use_kernel,
                       interpret=interpret, block=block)


def _fista_loop(body, init, iters, tol, check_every, residual):
    """Shared FISTA loop driver. `body` maps a (x, z, t) carry one
    iteration forward; with `tol=None` it runs the fixed `iters` budget
    in a fori_loop, otherwise `check_every`-iteration chunks of a
    while_loop that stops once `residual(x) <= tol`. The final chunk is
    truncated so `iters` is an EXACT ceiling. Returns (x, n_iters_run)."""
    if tol is None:
        carry = jax.lax.fori_loop(0, iters, lambda _, c: body(c), init)
        return carry[0], jnp.array(iters, jnp.int32)

    K = min(check_every, iters)

    def cond(state):
        _, it, res = state
        return jnp.logical_and(it < iters, res > tol)

    def chunk(state):
        carry, it, _ = state
        end = jnp.minimum(it + K, iters)
        carry = jax.lax.fori_loop(it, end, lambda _, c: body(c), carry)
        return carry, end, residual(carry[0])

    carry, n_iters, _ = jax.lax.while_loop(
        cond, chunk, (init, jnp.array(0, jnp.int32),
                      jnp.array(jnp.inf, init[0].dtype)))
    return carry[0], n_iters


def resolve_block_policy(m: int, p: int, r: int, dtype, block,
                         use_kernel: bool):
    """Engine v2 block policy: an explicit `block` (int or (bp, br, bk)
    triple) always wins; otherwise, when the kernel path is active, the
    autotuned winner for (backend, m, p, r, dtype) is looked up (and
    timed once on a miss). The oracle path never consults the cache."""
    from repro.kernels.ista_step.ops import is_ragged, resolve_blocks
    if block is not None:
        resolve_blocks(p, r, block)   # malformed blocks raise on EVERY
        return block                  # path, not just the kernel one
    if not use_kernel or is_ragged(p, r):
        # the kernel dispatcher routes ragged shapes to the jnp oracle,
        # which ignores blocks — never pay (or pollute) a sweep for them
        return 128
    from repro.kernels.autotune import autotune_block
    return autotune_block(m, p, r, dtype=dtype)


def resolve_logistic_block_policy(m: int, n: int, p: int, dtype, block,
                                  use_kernel: bool):
    """Block policy for the fused logistic-gradient kernel: an explicit
    `block` (int bn or (bn, bp) pair) wins; otherwise the autotuned
    (bn, bp) winner for (backend, m, n, p, dtype) when the kernel path
    is active. Same shape-routing caveats as `resolve_block_policy`:
    shapes the dispatcher routes to the oracle (ragged, sliver tiles,
    over the per-tile VMEM budget) never pay or pollute a sweep."""
    if block is not None:
        from repro.kernels.logistic_grad.ops import resolve_logistic_blocks
        resolve_logistic_blocks(n, p, block)   # validate on every path
        return block
    if not use_kernel or routes_to_oracle(n, p):
        return None
    from repro.kernels.autotune import autotune_logistic_block
    return autotune_logistic_block(m, n, p, dtype=dtype)


def resolve_rank_block_policy(m: int, n: int, p: int, dtype, block,
                              use_kernel: bool):
    """Block policy for the fused rank-n update kernel: an explicit
    `block` (int or (bp, bn) pair) wins; otherwise the autotuned winner
    for (backend, m, n, p, dtype) when the kernel path is active."""
    if block is not None:
        return block
    if not use_kernel or rank_routes_to_oracle(n, p):
        return 128
    from repro.kernels.autotune import autotune_rank_block
    return autotune_rank_block(m, n, p, dtype=dtype)


def solve_lasso_batched(Sigmas: jnp.ndarray, cs: jnp.ndarray, lam, *,
                        iters: int = 400, etas: jnp.ndarray | None = None,
                        beta0: jnp.ndarray | None = None,
                        use_kernel: bool | None = None,
                        interpret: bool | None = None,
                        block=None, tol=None, check_every: int = 25,
                        return_iters: bool = False) -> jnp.ndarray:
    """FISTA on a batch of sufficient-statistics lasso problems.

    Sigmas: (m, p, p); cs: (m, p) for one RHS per task or (m, p, r) for
    multi-RHS (the debias solve uses r = p with c = I). Returns an array
    shaped like `cs`.

    `etas` (m,) are per-task gradient step sizes; default 1/lambda_max
    per task. `lam` is a scalar or per-task (m,) weight; the proximal
    threshold is `etas * lam`. `beta0` warm-starts the iterates.
    `use_kernel` routes the fused step through the pallas kernel
    (default: only on TPU; the jnp batched step is the fast CPU path).

    Engine v2: every iteration is one fused prox + momentum step
    (`fista_step_batched`), bitwise-identical to the historical
    kernel-then-jnp-momentum pair. `block` is an int, an explicit
    (bp, br, bk) triple, or None for the autotuned per-shape policy.
    With `tol=` the fixed iteration budget becomes an exact ceiling:
    the loop runs in `check_every`-iteration chunks of a `while_loop`
    (final chunk truncated to the budget) and stops once the
    prox-gradient KKT residual max|x - soft(x - eta(Sigma x - c),
    eta lam)| drops to `tol`. `return_iters` additionally returns the
    number of iterations actually run.
    """
    m = cs.shape[0]
    r = 1 if cs.ndim == 2 else cs.shape[-1]
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    block = resolve_block_policy(m, cs.shape[1], r, cs.dtype, block,
                                 use_kernel)
    out, n_iters = _solve_lasso_batched(
        Sigmas, cs, lam, etas, beta0, tol, iters=iters,
        use_kernel=use_kernel, interpret=interpret, block=block,
        check_every=check_every)
    _record_solve("lasso", n_iters, iters)
    return (out, n_iters) if return_iters else out


@partial(jax.jit, static_argnames=("iters", "use_kernel", "interpret",
                                   "block", "check_every"))
def _solve_lasso_batched(Sigmas, cs, lam, etas, beta0, tol, *, iters,
                         use_kernel, interpret, block, check_every):
    squeeze = cs.ndim == 2
    C = cs[..., None] if squeeze else cs
    m = C.shape[0]
    if etas is None:
        etas = 1.0 / jnp.maximum(power_iteration_batched(Sigmas), 1e-12)
    etas = jnp.broadcast_to(jnp.asarray(etas, C.dtype).reshape(-1), (m,))

    if use_kernel:
        step = lambda Z, X, theta: fista_step_batched(
            Sigmas, Z, X, C, etas, lam, theta, block=block,
            interpret=interpret)
    else:
        step = lambda Z, X, theta: fista_step_batched_ref(
            Sigmas, Z, X, C, etas, lam, theta)

    if beta0 is None:
        X0 = jnp.zeros_like(C)
    else:
        b0 = beta0[..., None] if beta0.ndim == C.ndim - 1 else beta0
        X0 = jnp.broadcast_to(b0, C.shape).astype(C.dtype)

    def body(carry):
        x, z, t = carry
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        x_next, z_next = step(z, x, (t - 1.0) / t_next)
        return x_next, z_next, t_next

    def residual(x):
        # prox-gradient KKT residual: zero iff x is the lasso optimum
        x_fp = ista_step_batched_ref(Sigmas, x, C, etas, lam)
        return jnp.max(jnp.abs(x_fp - x))

    x, n_iters = _fista_loop(body, (X0, X0, jnp.array(1.0, C.dtype)),
                             iters, tol, check_every, residual)
    return (x[..., 0] if squeeze else x), n_iters


def solve_lasso_grid(Sigmas: jnp.ndarray, cs: jnp.ndarray,
                     lams: jnp.ndarray, *, iters: int = 400,
                     etas: jnp.ndarray | None = None,
                     use_kernel: bool | None = None,
                     interpret: bool | None = None,
                     block=None) -> jnp.ndarray:
    """Solve every (task, lambda) pair of a tuning grid in ONE batch.

    Sigmas (m, p, p), cs (m, p), lams (k,) -> (k, m, p). The engine
    takes per-task regularization weights, so a lambda grid is just k*m
    tasks sharing tiled statistics — the whole regularization-path sweep
    (lam = 0 included) costs one engine call instead of k solver runs.
    Step sizes depend only on Sigma and are shared across the grid.

    Like every public engine entry point this is an EAGER wrapper over
    a jitted inner solve: policy resolution (backend default, autotune
    lookup) and telemetry happen out here with concrete values, the
    math compiles once in `_solve_lasso_grid`.
    """
    m, p = cs.shape
    lams = jnp.asarray(lams, cs.dtype)
    k = lams.shape[0]
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    block = resolve_block_policy(k * m, p, 1, cs.dtype, block, use_kernel)
    B = _solve_lasso_grid(Sigmas, cs, lams, etas, iters=iters,
                          use_kernel=use_kernel, interpret=interpret,
                          block=block)
    _record_solve("lasso_grid", iters, iters)
    return B


@partial(jax.jit, static_argnames=("iters", "use_kernel", "interpret",
                                   "block"))
def _solve_lasso_grid(Sigmas, cs, lams, etas, *, iters, use_kernel,
                      interpret, block):
    m, p = cs.shape
    lams = jnp.asarray(lams, cs.dtype)
    k = lams.shape[0]
    if etas is None:
        etas = 1.0 / jnp.maximum(power_iteration_batched(Sigmas), 1e-12)
    Sig_g = jnp.tile(Sigmas, (k, 1, 1))
    cs_g = jnp.tile(cs, (k, 1))
    etas_g = jnp.tile(jnp.asarray(etas, cs.dtype).reshape(-1), (k,))
    lam_g = jnp.repeat(lams, m)
    B, _ = _solve_lasso_batched(Sig_g, cs_g, lam_g, etas_g, None, None,
                                iters=iters, use_kernel=use_kernel,
                                interpret=interpret, block=block,
                                check_every=25)
    return B.reshape(k, m, p)


def solve_lasso_eq2(Sigmas: jnp.ndarray, cs: jnp.ndarray, lam, *,
                    iters: int = 400,
                    beta0: jnp.ndarray | None = None,
                    lam_max: jnp.ndarray | None = None,
                    tol=None, check_every: int = 25,
                    return_iters: bool = False) -> jnp.ndarray:
    """Batched lasso in the PAPER'S eq.-2 convention:

        (1/n)||y_t - X_t b||^2 + lam ||b||_1

    on sufficient statistics. Owns the translation into the engine's
    normalized-gradient convention — step 2/max(2*lambda_max, eps),
    threshold weight lam/2 — so callers can never mismatch the pair
    (passing an unhalved lam with the eq.-2 step runs at double the
    intended regularization with no error). `beta0` (m, p) warm-starts
    the FISTA iterates (streaming refits restart from the previous
    solution). `lam_max` (m,) are precomputed per-task largest
    eigenvalues; callers that also run the debias solve pass one shared
    power iteration instead of paying it twice.

    `tol=` turns `iters` into an exact CEILING via the engine's
    chunked-while-loop early exit (prox-gradient KKT residual checked
    every `check_every` iterations) — this is the latency-budget lever
    the streaming refit path leans on: a warm-started refit under a tol
    exits in a fraction of the ceiling, and the ceiling bounds the
    worst case. `return_iters` also returns the iterations run."""
    m, p = cs.shape
    use_kernel = jax.default_backend() == "tpu"
    block = resolve_block_policy(m, p, 1, cs.dtype, None, use_kernel)
    out, n_iters = _solve_lasso_eq2(Sigmas, cs, lam, beta0, lam_max, tol,
                                    iters=iters, use_kernel=use_kernel,
                                    block=block, check_every=check_every)
    _record_solve("lasso_eq2", n_iters, iters)
    return (out, n_iters) if return_iters else out


@partial(jax.jit, static_argnames=("iters", "use_kernel", "block",
                                   "check_every"))
def _solve_lasso_eq2(Sigmas, cs, lam, beta0, lam_max, tol, *, iters,
                     use_kernel, block, check_every):
    if lam_max is None:
        etas = jax.vmap(lasso_stats_step_scale)(Sigmas)
    else:
        etas = 2.0 / jnp.maximum(2.0 * lam_max, 1e-12)
    return _solve_lasso_batched(Sigmas, cs, 0.5 * jnp.asarray(lam),
                                etas, beta0, tol, iters=iters,
                                use_kernel=use_kernel, interpret=None,
                                block=block, check_every=check_every)


def solve_lasso_eq2_grid(Sigmas: jnp.ndarray, cs: jnp.ndarray, lams, *,
                         iters: int = 400) -> jnp.ndarray:
    """`solve_lasso_grid` in the paper's eq.-2 convention (see
    `solve_lasso_eq2`). Sigmas (m, p, p), cs (m, p), lams (k,) ->
    (k, m, p)."""
    m, p = cs.shape
    lams = jnp.asarray(lams, cs.dtype)
    k = lams.shape[0]
    use_kernel = jax.default_backend() == "tpu"
    block = resolve_block_policy(k * m, p, 1, cs.dtype, None, use_kernel)
    out = _solve_lasso_eq2_grid(Sigmas, cs, lams, iters=iters,
                                use_kernel=use_kernel, block=block)
    _record_solve("lasso_eq2_grid", iters, iters)
    return out


@partial(jax.jit, static_argnames=("iters", "use_kernel", "block"))
def _solve_lasso_eq2_grid(Sigmas, cs, lams, *, iters, use_kernel, block):
    etas = jax.vmap(lasso_stats_step_scale)(Sigmas)
    return _solve_lasso_grid(Sigmas, cs, 0.5 * lams, etas, iters=iters,
                             use_kernel=use_kernel, interpret=None,
                             block=block)


def solve_logistic_lasso_batched(Xs: jnp.ndarray, ys: jnp.ndarray, lam, *,
                                 iters: int = 600,
                                 etas: jnp.ndarray | None = None,
                                 beta0: jnp.ndarray | None = None,
                                 grad_scale=1.0, prox=None,
                                 momentum: bool = True, tol=None,
                                 check_every: int = 25,
                                 use_kernel: bool | None = None,
                                 interpret: bool | None = None,
                                 block=None,
                                 return_iters: bool = False):
    """One FISTA loop for a whole batch of l1-logistic regressions.

    Xs (m, n, p), ys (m, n) in {-1, +1}; lam scalar or per-task (m,).
    Returns B (m, p). The logistic loss is not a function of (Sigma, c)
    alone, so the gradient re-touches the raw samples — but as ONE
    all-tasks gradient `-X'(y sigmoid(-y Xb))/n` per iteration instead
    of a vmap of m per-task FISTA loops, with per-task step sizes
    `1 / max(lambda_max(Sigma)/4, eps)` from one shared batched power
    iteration (the logistic Hessian is bounded by Sigma/4). On the
    kernel path (`use_kernel`, default only on TPU) the gradient is the
    fused Pallas `kernels/logistic_grad` kernel — forward matvec,
    sigmoid residual, and back-projection in one dispatch over each
    resident X slab (feature-tiled past the VMEM budget, so the p >> n
    regime stays on the kernel); otherwise it is the bitwise-identical
    jnp einsum oracle (the fast CPU path). `block` is an int sample
    tile bn, a (bn, bp) pair, or None for the autotuned per-shape
    policy (DESIGN.md §11-§12).

    `beta0` (m, p) warm-starts the iterates (streaming refits restart
    from the previous generation). `prox` overrides the elementwise
    soft threshold — signature `prox(B (m, p), steps (m, 1)) -> (m, p)`
    — which is how the group-lasso / iCAP / masked-refit variants reuse
    this loop. `prox` is a STATIC jit argument hashed by identity:
    when calling eagerly in a loop, pass one reused function object
    (not a fresh lambda per call) or every call retraces. `grad_scale`
    rescales the gradient (the multi-task objectives divide by m);
    `momentum=False` degrades FISTA to plain proximal gradient (the
    masked refit's historical iteration). As in
    `solve_lasso_batched`, `tol=` stops early on the prox-gradient
    fixed-point residual every `check_every` iterations, and
    `return_iters` also returns the iterations run.
    """
    m, n, p = Xs.shape
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    block = resolve_logistic_block_policy(m, n, p, Xs.dtype, block,
                                          use_kernel)
    out, n_iters = _solve_logistic_lasso_batched(
        Xs, ys, lam, etas, beta0, grad_scale, tol, iters=iters, prox=prox,
        momentum=momentum, check_every=check_every, use_kernel=use_kernel,
        interpret=interpret, block=block)
    _record_solve("logistic", n_iters, iters)
    return (out, n_iters) if return_iters else out


@partial(jax.jit, static_argnames=("iters", "momentum", "prox",
                                   "check_every", "use_kernel",
                                   "interpret", "block"))
def _solve_logistic_lasso_batched(Xs, ys, lam, etas, beta0, grad_scale,
                                  tol, *, iters, prox, momentum,
                                  check_every, use_kernel, interpret,
                                  block):
    m, n, p = Xs.shape
    lam_t = jnp.broadcast_to(jnp.asarray(lam, Xs.dtype).reshape(-1), (m,))
    if etas is None:
        Sigmas, _ = sufficient_stats(Xs, ys)
        L = 0.25 * power_iteration_batched(Sigmas)
        etas = 1.0 / jnp.maximum(L, 1e-12)
    S = jnp.broadcast_to(jnp.asarray(etas, Xs.dtype).reshape(-1),
                         (m,))[:, None]

    if use_kernel:
        graw = lambda B: logistic_grad(Xs, ys, B, block=block,
                                       interpret=interpret)
    else:
        graw = lambda B: logistic_grad_ref(Xs, ys, B)
    grad = lambda B: graw(B) * grad_scale

    if prox is None:
        prox = lambda V, steps: soft_threshold(V, steps * lam_t[:, None])

    X0 = jnp.zeros((m, p), Xs.dtype) if beta0 is None \
        else beta0.astype(Xs.dtype)

    def body(carry):
        x, z, t = carry
        t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        x_next = prox(z - S * grad(z), S)
        z_next = x_next + ((t - 1.0) / t_next) * (x_next - x) \
            if momentum else x_next
        return x_next, z_next, t_next

    def residual(x):
        return jnp.max(jnp.abs(prox(x - S * grad(x), S) - x))

    return _fista_loop(body, (X0, X0, jnp.array(1.0, Xs.dtype)),
                       iters, tol, check_every, residual)


def debias_batched(Sigmas: jnp.ndarray, cs: jnp.ndarray,
                   beta_hat: jnp.ndarray, Ms: jnp.ndarray) -> jnp.ndarray:
    """Debiased estimates (paper eq. 4) from sufficient statistics:

        b_u = b + M (c - Sigma b)        [ = b + n^-1 M X'(y - X b) ]

    Sigmas (m, p, p), cs/beta_hat (m, p), Ms (m, p, p) -> (m, p).
    """
    resid_corr = cs - jnp.einsum("tij,tj->ti", Sigmas, beta_hat)
    return beta_hat + jnp.einsum("tij,tj->ti", Ms, resid_corr)


def scaled_identity_m0(Sigmas: jnp.ndarray) -> jnp.ndarray:
    """Default M warm start: identity scaled by 1/diag(Sigma) per task
    (diagonal, so it is its own transpose in either M/C convention)."""
    m, p, _ = Sigmas.shape
    eye = jnp.broadcast_to(jnp.eye(p, dtype=Sigmas.dtype), (m, p, p))
    return eye / jnp.maximum(
        jnp.diagonal(Sigmas, axis1=-2, axis2=-1), 1e-12)[:, None, :]


def inverse_hessian_batched(Sigmas: jnp.ndarray, mu, iters: int = 600,
                            M0: jnp.ndarray | None = None,
                            lam_max: jnp.ndarray | None = None,
                            tol=None, check_every: int = 25,
                            return_iters: bool = False) -> jnp.ndarray:
    """Approximate inverse Ms (m, p, p) of a stack of PSD covariances —
    the Javanmard-Montanari program for all tasks and all p rows as ONE
    multi-RHS batched solve (m*p right-hand sides). `M0` warm-starts the
    solve (e.g. the previous generation's Ms in a streaming refit);
    default is the scaled identity of the single-task solver. `lam_max`
    (m,) lets callers share one power iteration with the lasso solve.
    `tol=` makes `iters` a ceiling (early exit on the KKT residual,
    checked every `check_every` iterations) so a warm-started streaming
    refit pays only the iterations it needs; `return_iters` also
    returns the iterations run."""
    m, p, _ = Sigmas.shape
    use_kernel = jax.default_backend() == "tpu"
    block = resolve_block_policy(m, p, p, Sigmas.dtype, None, use_kernel)
    out, n_iters = _inverse_hessian_batched(
        Sigmas, mu, M0, lam_max, tol, iters=iters,
        use_kernel=use_kernel, block=block, check_every=check_every)
    _record_solve("debias", n_iters, iters)
    return (out, n_iters) if return_iters else out


@partial(jax.jit, static_argnames=("iters", "use_kernel", "block",
                                   "check_every"))
def _inverse_hessian_batched(Sigmas, mu, M0, lam_max, tol, *, iters,
                             use_kernel, block, check_every):
    m, p, _ = Sigmas.shape
    if lam_max is None:
        lam_max = power_iteration_batched(Sigmas)
    etas = 1.0 / jnp.maximum(lam_max, 1e-12)
    eye = jnp.broadcast_to(jnp.eye(p, dtype=Sigmas.dtype), (m, p, p))
    C0 = scaled_identity_m0(Sigmas) if M0 is None else \
        jnp.swapaxes(M0, -1, -2)
    Cs, n_iters = _solve_lasso_batched(Sigmas, eye, mu, etas, C0, tol,
                                       iters=iters, use_kernel=use_kernel,
                                       interpret=None, block=block,
                                       check_every=check_every)
    return jnp.swapaxes(Cs, -1, -2), n_iters
