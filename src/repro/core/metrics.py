"""Evaluation metrics used in the paper's experiments (Figures 1-3)."""
from __future__ import annotations

import jax.numpy as jnp


def support_of(B: jnp.ndarray, tol: float = 1e-6) -> jnp.ndarray:
    """Estimated support from a (p, m) coefficient matrix (row-wise)."""
    return jnp.linalg.norm(B, axis=-1) > tol


def hamming(support_hat: jnp.ndarray, support_true: jnp.ndarray) -> jnp.ndarray:
    """Hamming distance between supports (# of disagreeing variables)."""
    return jnp.sum(support_hat != support_true)


def estimation_error(B_hat: jnp.ndarray, B_true: jnp.ndarray) -> jnp.ndarray:
    """l1/l2 error sum_j ||Bhat_j - B_j||_2 (paper Corollary 2). (p, m) args."""
    return jnp.sum(jnp.linalg.norm(B_hat - B_true, axis=-1))


def prediction_error(B_hat: jnp.ndarray, B_true: jnp.ndarray,
                     Sigma: jnp.ndarray) -> jnp.ndarray:
    """Population prediction risk (1/m) sum_t (b_t - b*_t)' Sigma (b_t - b*_t)."""
    D = B_hat - B_true                       # (p, m)
    return jnp.mean(jnp.einsum("pt,pq,qt->t", D, Sigma, D))


def classification_error(B_hat: jnp.ndarray, Xs: jnp.ndarray,
                         ys: jnp.ndarray) -> jnp.ndarray:
    """Average 0/1 error on held-out data. Xs: (m,n,p), ys: (m,n) in {-1,1}."""
    logits = jnp.einsum("tnp,pt->tn", Xs, B_hat)
    return jnp.mean(jnp.sign(logits) != ys)
