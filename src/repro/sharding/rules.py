"""Sharding rules: map parameter/activation/cache pytrees to PartitionSpecs.

Scheme (see DESIGN.md §4):
  * `model` axis — tensor parallel: attention heads, ffn width, experts,
    vocab (embedding rows / head columns), decode-cache sequence.
  * `data` axis — FSDP: the d_model dimension of weight matrices, batch
    dimension of activations.
  * `pod` axis (multi-pod mesh) — pure data parallelism: parameters are
    REPLICATED across pods (grad all-reduce crosses the DCN once per
    step); the batch is sharded over (pod, data).

Rules are rank-aligned from the RIGHT so stacked per-layer parameters
(leading scan axis) inherit the same spec with a leading None. Every
proposed axis is validated for divisibility against the actual dim size;
rules may carry fallback proposals (embed/head vocab padding aside), and
axes that still do not divide are DROPPED (replicated) — see fit_spec for
why moving TP onto head_dim is worse than replicating a small projection.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel activation axes: ('pod', 'data') when multi-pod."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _align(shape: Sequence[int], right: Sequence) -> list:
    nd = len(shape)
    spec: list = [None] * nd
    take = min(len(right), nd)
    if take:
        spec[nd - take:] = list(right[len(right) - take:])
    return spec


def _fits(shape: Sequence[int], spec: Sequence, mesh: Mesh) -> bool:
    return all(ax is None or shape[i] % _axis_size(mesh, ax) == 0
               for i, ax in enumerate(spec))


def fit_spec(shape: Sequence[int], right: Sequence, mesh: Mesh) -> P:
    """Right-align `right` onto `shape`; axes that do not divide their dim
    are DROPPED (replicated), never moved to another dim — moving TP onto
    e.g. the head_dim makes RoPE's half-split reshard every layer (GSPMD
    'involuntary full rematerialization'). Replicating the offending
    (small) projection matches production TP practice for GQA with
    kv_heads < TP degree."""
    spec = _align(shape, right)
    for i, ax in enumerate(spec):
        if ax is not None and shape[i] % _axis_size(mesh, ax) != 0:
            spec[i] = None
    return P(*spec)


def fit_first(shape: Sequence[int], proposals: Sequence[Sequence],
              mesh: Mesh) -> P:
    """Try each proposal in order; first that fully divides wins. If none
    fits, fall back to the first proposal with failing axes dropped."""
    for right in proposals:
        spec = _align(shape, right)
        if _fits(shape, spec, mesh):
            return P(*spec)
    return fit_spec(shape, proposals[0], mesh)


# (path-substring, proposal list) — first path match wins; within a match,
# the first proposal whose axes all divide is used (else axes are dropped).
_PARAM_RULES: Tuple[Tuple[str, Tuple[Tuple[Optional[str], ...], ...]], ...] = (
    # MoE expert stacks (E, d, f) / (E, f, d): experts over `model` (EP)
    ("experts/w_down", (("model", None, "data"),)),
    ("experts/",       (("model", "data", None),)),
    ("router",         ((None, "model"),)),
    # attention projections
    ("wq", (("data", "model", None),)),
    ("wk", (("data", "model", None),)),
    ("wv", (("data", "model", None),)),
    ("wo", (("model", None, "data"),)),
    # dense mlp / shared experts / griffin gate+in projections
    ("w_down", (("model", "data"),)),
    ("w_gate", (("data", "model"),)),
    ("w_up",   (("data", "model"),)),
    # griffin rg-lru
    ("rec/w_x", (("data", "model"),)),
    ("rec/w_a", ((None, "model"),)),
    ("rec/w_i", ((None, "model"),)),
    ("rec/w_o", (("model", "data"),)),
    ("rec/conv_w", ((None, "model"),)),
    ("rec/b_a", (("model",),)),
    ("rec/b_i", (("model",),)),
    ("rec/lam", (("model",),)),
    # mamba2 ssd
    ("ssd/w_in",  (("data", "model"),)),
    ("ssd/w_out", (("model", "data"),)),
    ("ssd/conv_w", ((None, "model"),)),
    ("ssd/dt_bias", (("model",),)),
    ("ssd/A_log", (("model",),)),
    ("ssd/D", (("model",),)),
    # embeddings: vocab over model, d_model over data(fsdp);
    # odd vocab sizes fall back to sharding d_model over BOTH axes
    ("embed", (("model", "data"), (None, ("data", "model")))),
    ("head",  (("data", "model"), (("data", "model"), None))),
    # norms replicated
    ("norm", ((),)),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _spec_for(path: str, shape, mesh: Mesh) -> P:
    for frag, proposals in _PARAM_RULES:
        if frag in path:
            return fit_first(shape, proposals, mesh)
    return P()  # replicate by default


def _strip_data(spec: P) -> P:
    """Remove the `data` axis from a spec (ZeRO-1: bf16 params are
    replicated over data; TP over model only)."""
    def strip(ax):
        if ax == "data":
            return None
        if isinstance(ax, tuple):
            kept = tuple(a for a in ax if a != "data")
            return kept[0] if len(kept) == 1 else (kept or None)
        return ax
    return P(*[strip(ax) for ax in spec])


def opt_pspecs(params_tree, mesh: Mesh):
    """ZeRO-sharded specs (model TP + data sharding) for master/moments."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), leaf.shape, mesh),
        params_tree)


def param_pspecs(params_tree, mesh: Mesh):
    """bf16 forward-parameter specs: TP over `model`, replicated over
    `data`/`pod` (ZeRO-1, see optim.adamw)."""
    return jax.tree.map(_strip_data, opt_pspecs(params_tree, mesh),
                        is_leaf=lambda x: isinstance(x, P))


def train_state_pspecs(state, mesh: Mesh):
    from repro.optim.adamw import AdamWState
    from repro.training.step import TrainState
    pspecs = param_pspecs(state.params, mesh)
    ospecs = opt_pspecs(state.params, mesh)
    return TrainState(
        params=pspecs,
        opt=AdamWState(master=ospecs, mu=ospecs, nu=ospecs, count=P()),
        step=P(),
    )


def _dp_or_none(mesh: Mesh, batch_size: int):
    dp = dp_axes(mesh)
    total = 1
    for a in dp:
        total *= mesh.shape[a]
    return dp if batch_size % total == 0 and batch_size >= total else None


def batch_pspecs(mesh: Mesh, batch_size: int, has_frontend: bool = False):
    """Batch sharding: batch over (pod, data)."""
    from repro.models import Batch
    b = _dp_or_none(mesh, batch_size)
    tok = P(b, None)
    return Batch(tokens=tok, labels=tok,
                 frontend=P(b, None, None) if has_frontend else None)


def logits_pspec(mesh: Mesh, vocab: int, seq: int) -> P:
    """(B, S, V): batch over dp; vocab over model, falling back to the
    sequence dim when the vocab is not divisible (odd vocab sizes)."""
    if vocab % mesh.shape["model"] == 0:
        return P(dp_axes(mesh), None, "model")
    if seq % mesh.shape["model"] == 0:
        return P(dp_axes(mesh), "model", None)
    return P(dp_axes(mesh), None, None)


def cache_pspecs(mesh: Mesh, caches, batch_size: int):
    """Decode caches: batch over dp (if divisible), cache seq over model.

    KVCache k/v (B, S, K, H) -> P(dp, 'model', None, None) (seq-parallel)
    slot_pos (S,)            -> P() (replicated, tiny)
    Recurrent h (B, D)       -> P(dp, 'model')
    conv (B, k, D)           -> P(dp, None, 'model')
    Ssd state (B, H, P, N)   -> P(dp, 'model', None, None)
    enc_out (B, F, d)        -> P(dp, None, None)
    """
    b = _dp_or_none(mesh, batch_size)
    # field-name rules, right-aligned: stacked (L, ...) leaves inherit a
    # leading None automatically. KV k/v (B,S,K,H): seq over model
    # (sequence-parallel cache); SSD state (B,H,P,N): heads over model;
    # conv carry (B,k-1,D): channels over model; RG-LRU h (B,D) likewise.
    rules = (
        ("slot_pos", None),
        ("enc_out", (b, None, None)),
        ("/k", (b, "model", None, None)),
        ("/v", (b, "model", None, None)),
        ("state", (b, "model", None, None)),
        ("conv", (b, None, "model")),
        ("/h", (b, "model")),
    )

    def spec(leaf_path, leaf):
        path = _path_str(leaf_path)
        for frag, right in rules:
            if frag in path or path.endswith(frag.strip("/")):
                if right is None:
                    return P()
                return fit_spec(leaf.shape, right, mesh)
        return P()

    return jax.tree_util.tree_map_with_path(spec, caches)


def named(mesh: Mesh, tree):
    """PartitionSpec pytree -> NamedSharding pytree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        tree, is_leaf=lambda x: isinstance(x, P))
