"""Serving steps: prefill and single-token decode (the units the dry-run
lowers for the inference shapes), plus a simple batched greedy engine for
the runnable examples."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import (
    Batch, forward_decode, forward_prefill, init_caches,
)
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, cache_len: Optional[int] = None):
    def prefill_step(params, batch: Batch):
        logits, caches = forward_prefill(params, cfg, batch,
                                         cache_len=cache_len)
        return logits, caches
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """ONE new token against a pre-existing KV/state cache."""
    def serve_step(params, token, pos, caches):
        logits, caches = forward_decode(params, cfg, token, pos, caches)
        next_token = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_token, logits, caches
    return serve_step


def greedy_generate(params, cfg: ModelConfig, prompt: jnp.ndarray,
                    steps: int, cache_extra: int = 0,
                    frontend: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Batched greedy decoding. prompt: (B, S) -> (B, S + steps).

    The prefill's last logits already yield token 0, so only steps - 1
    decode iterations run: the scan's stacked pre-update tokens are
    [tok0 .. tok_{steps-2}] and the final carry is tok_{steps-1} (an
    earlier version decoded a `steps`-th token only to slice it away).
    `cache_extra` pads the cache past the written range — decode writes
    stop at position S + off + steps - 2 — so it never shifts positions
    or tokens; `steps=0` returns the prompt unchanged (the `[:, :steps]`
    slice drops tok0, and the prefill still runs for cache warmup
    parity with the steps > 0 path).
    """
    B, S = prompt.shape
    off = cfg.n_frontend_tokens if cfg.arch_type == "vlm" and frontend is not None else 0
    cache_len = S + off + steps + cache_extra
    logits, caches = forward_prefill(params, cfg,
                                     Batch(tokens=prompt, frontend=frontend),
                                     cache_len=cache_len)
    tok0 = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
    serve_step = make_serve_step(cfg)

    def body(carry, i):
        tok, caches = carry
        pos = (S + off + i).astype(jnp.int32)
        nxt, _, caches = serve_step(params, tok[:, None], pos, caches)
        return (nxt, caches), tok

    (last, _), toks = jax.lax.scan(body, (tok0, caches),
                                   jnp.arange(max(steps - 1, 0),
                                              dtype=jnp.int32))
    gen = jnp.concatenate([toks.T, last[:, None]], axis=1)[:, :steps]
    return jnp.concatenate([prompt, gen], axis=1)
