"""Architecture registry: full production configs + reduced smoke variants.

Every full config reproduces the assignment spec exactly; `smoke()`
returns a same-family reduced variant (<=2 layers, d_model<=512,
<=4 experts) for CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

from repro.models.config import ModelConfig, MoeConfig, RglruConfig, SsdConfig

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(fn: Callable[[], ModelConfig]) -> Callable[[], ModelConfig]:
    _REGISTRY[fn().name] = fn          # key by the config's canonical name
    return fn


def get_config(name: str) -> ModelConfig:
    key = name if name in _REGISTRY else name.replace("_", "-")
    return _REGISTRY[key]()


def list_archs():
    return sorted(_REGISTRY)


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family variant: 2 layers, d_model<=512, <=4 experts."""
    kw: dict = dict(
        n_layers=2, d_model=256,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads, 2)),
        d_ff=512, vocab=512, head_dim=64,
    )
    if cfg.arch_type == "hybrid":
        kw["n_layers"] = 3            # one full (rec, rec, local_attn) group
        kw["rglru"] = RglruConfig(d_rnn=256, conv_kernel=4)
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=2,
            n_shared=min(cfg.moe.n_shared, 1), d_expert=128,
            first_k_dense=min(cfg.moe.first_k_dense, 1))
    if cfg.ssd is not None:
        kw["ssd"] = dataclasses.replace(
            cfg.ssd, n_heads=4, head_dim=32, state_dim=16, chunk=16)
        kw["n_heads"] = 4
    if cfg.arch_type == "encdec":
        kw["n_encoder_layers"] = 2
        kw["n_frontend_tokens"] = 16
    if cfg.arch_type == "vlm":
        kw["n_frontend_tokens"] = 16
    if cfg.window:
        kw["window"] = 32
    return cfg.replace(**kw)


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

@register
def minitron_4b() -> ModelConfig:
    """Pruned Nemotron: squared-ReLU MLP, GQA [arXiv:2407.14679]."""
    return ModelConfig(
        name="minitron-4b", arch_type="dense", n_layers=32, d_model=3072,
        n_heads=24, n_kv_heads=8, head_dim=128, d_ff=9216, vocab=256000,
        mlp_act="squared_relu", source="arXiv:2407.14679")


@register
def nemotron_4_15b() -> ModelConfig:
    """Nemotron-4 15B: GQA, squared-ReLU [arXiv:2402.16819]."""
    return ModelConfig(
        name="nemotron-4-15b", arch_type="dense", n_layers=32, d_model=6144,
        n_heads=48, n_kv_heads=8, d_ff=24576, vocab=256000,
        mlp_act="squared_relu", source="arXiv:2402.16819")


@register
def deepseek_67b() -> ModelConfig:
    """DeepSeek 67B: llama-arch, GQA [arXiv:2401.02954]."""
    return ModelConfig(
        name="deepseek-67b", arch_type="dense", n_layers=95, d_model=8192,
        n_heads=64, n_kv_heads=8, d_ff=22016, vocab=102400,
        mlp_act="swiglu", source="arXiv:2401.02954")


@register
def granite_3_2b() -> ModelConfig:
    """Granite 3.0 2B base: GQA [hf:ibm-granite/granite-3.0-2b-base]."""
    return ModelConfig(
        name="granite-3-2b", arch_type="dense", n_layers=40, d_model=2048,
        n_heads=32, n_kv_heads=8, d_ff=8192, vocab=49155,
        mlp_act="swiglu", source="hf:ibm-granite/granite-3.0-2b-base")


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

@register
def deepseek_moe_16b() -> ModelConfig:
    """DeepSeekMoE 16B: fine-grained, 2 shared + 64 routed top-6, first
    layer dense [arXiv:2401.06066]."""
    return ModelConfig(
        name="deepseek-moe-16b", arch_type="moe", n_layers=28, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1408 * 8, vocab=102400,
        mlp_act="swiglu",
        moe=MoeConfig(n_experts=64, top_k=6, n_shared=2, d_expert=1408,
                      first_k_dense=1),
        source="arXiv:2401.06066")


@register
def qwen3_moe_30b_a3b() -> ModelConfig:
    """Qwen3-30B-A3B: 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B]."""
    return ModelConfig(
        name="qwen3-moe-30b-a3b", arch_type="moe", n_layers=48, d_model=2048,
        n_heads=32, n_kv_heads=4, head_dim=128, d_ff=768 * 8, vocab=151936,
        mlp_act="swiglu",
        moe=MoeConfig(n_experts=128, top_k=8, n_shared=0, d_expert=768),
        source="hf:Qwen/Qwen3-30B-A3B")


# ---------------------------------------------------------------------------
# audio enc-dec / VLM (frontends are stubs per DESIGN.md §6)
# ---------------------------------------------------------------------------

@register
def seamless_m4t_medium() -> ModelConfig:
    """SeamlessM4T-medium backbone: 12L enc + 12L dec, multimodal
    [arXiv:2308.11596]. Audio frontend = stub frame embeddings."""
    return ModelConfig(
        name="seamless-m4t-medium", arch_type="encdec", n_layers=12,
        d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
        mlp_act="gelu", n_encoder_layers=12, cross_attention=True,
        frontend="audio", n_frontend_tokens=4096,
        source="arXiv:2308.11596")


@register
def internvl2_2b() -> ModelConfig:
    """InternVL2-2B language backbone (InternLM2-1.8B dims); InternViT
    frontend = stub patch embeddings [arXiv:2404.16821]."""
    return ModelConfig(
        name="internvl2-2b", arch_type="vlm", n_layers=24, d_model=2048,
        n_heads=16, n_kv_heads=8, d_ff=8192, vocab=92553,
        mlp_act="swiglu", frontend="vision", n_frontend_tokens=1024,
        source="arXiv:2404.16821")


# ---------------------------------------------------------------------------
# hybrid / SSM
# ---------------------------------------------------------------------------

@register
def recurrentgemma_9b() -> ModelConfig:
    """RecurrentGemma-9B: RG-LRU + local attention 1:2 (pattern
    rec,rec,local-attn), MQA [arXiv:2402.19427]."""
    return ModelConfig(
        name="recurrentgemma-9b", arch_type="hybrid", n_layers=38,
        d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256, d_ff=12288,
        vocab=256000, mlp_act="geglu", window=2048,
        layer_pattern=("recurrent", "recurrent", "local_attn"),
        rglru=RglruConfig(d_rnn=4096, conv_kernel=4),
        source="arXiv:2402.19427")


@register
def mamba2_1_3b() -> ModelConfig:
    """Mamba2-1.3B: SSD, 48 layers, attention-free [arXiv:2405.21060]."""
    return ModelConfig(
        name="mamba2-1.3b", arch_type="ssm", n_layers=48, d_model=2048,
        n_heads=64, n_kv_heads=0, d_ff=0, vocab=50280,
        ssd=SsdConfig(state_dim=128, head_dim=64, n_heads=64, n_groups=1,
                      chunk=128, conv_kernel=4, expand=2),
        source="arXiv:2405.21060")


ASSIGNED = [
    "minitron-4b", "deepseek-moe-16b", "nemotron-4-15b", "qwen3-moe-30b-a3b",
    "seamless-m4t-medium", "internvl2-2b", "recurrentgemma-9b",
    "deepseek-67b", "granite-3-2b", "mamba2-1.3b",
]
