"""Architecture configs (assigned pool + the paper's own experiment config)."""
from repro.configs.registry import ASSIGNED, get_config, list_archs, smoke

__all__ = ["ASSIGNED", "get_config", "list_archs", "smoke"]
