"""Streaming DSML: online sufficient-statistics estimation and serving.

The paper's statistics `(Sigma, c)` are additive over samples, so the
whole DSML pipeline runs online: minibatches fold into a fixed-size
`StreamState` (optionally decayed / windowed / SPMD-reduced over a
data x task mesh), and `refit` re-runs Algorithm 1 from the state with
warm starts. `StreamingDsmlService` is the serving driver. DESIGN.md §9.
"""
from repro.stream.accumulate import (
    accumulate_stats_fn, accumulate_stats_sharded, ingest_sharded,
)
from repro.stream.guard import IngestGuard, QuarantineRecord
from repro.stream.health import RefitHealth, refit_health
from repro.stream.refit import (
    RefitInfo, jaccard_support, refit, refit_logistic,
)
from repro.stream.serve import (
    ModelGeneration, ServeResult, ServingFront, bucket_rows,
)
from repro.stream.service import StreamingDsmlService
from repro.stream.state import (
    StreamState, WindowState, ingest, ingest_stats, init_stream_state,
    init_window, merge, window_ingest, window_stats,
)

__all__ = [
    "accumulate_stats_fn", "accumulate_stats_sharded", "ingest_sharded",
    "IngestGuard", "QuarantineRecord",
    "RefitHealth", "refit_health",
    "RefitInfo", "jaccard_support", "refit", "refit_logistic",
    "ModelGeneration", "ServeResult", "ServingFront", "bucket_rows",
    "StreamingDsmlService",
    "StreamState", "WindowState", "ingest", "ingest_stats",
    "init_stream_state", "init_window", "merge", "window_ingest",
    "window_stats",
]
