"""StreamingDsmlService: the online DSML loop as a servable driver.

Ties the streaming pieces together around one `StreamState`:

    ingest loop     raw minibatches fold into the state (host path,
                    decayed, sliding-window, or SPMD over a data x task
                    mesh via `stream.accumulate`);
    refit policy    a refit runs every `refit_every` ingested samples;
                    when the refreshed support has not drifted
                    (jaccard >= 1 - drift_threshold) the interval
                    doubles, up to `max_refit_interval` — stationary
                    traffic converges to rare refits, a support shift
                    snaps the cadence back to the base rate;
    warm starts     generation-0 refits run the full cold budget,
                    later ones warm-start both solves (lasso from
                    `beta_local`, debias from `Ms`) with the
                    `warm_*_iters` budgets (default: a quarter);
    serving         `predict` applies the current `beta_tilde`;
    persistence     `save`/`load` round-trip the state through
                    `checkpoint/io` (npz), so a restarted service
                    resumes serving and refitting without replay.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.checkpoint.io import restore_pytree, save_pytree
from repro.stream.accumulate import ingest_sharded
from repro.stream.refit import RefitInfo, refit
from repro.stream.state import (
    StreamState, init_stream_state, init_window, ingest, window_ingest,
    window_stats,
)


@jax.jit
def _predict_tasks(beta_tilde: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("tnp,tp->tn", X, beta_tilde)


@jax.jit
def _predict_shared(beta_tilde: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("np,tp->tn", X, beta_tilde)


class StreamingDsmlService:
    """Online DSML over continuously arriving multi-task traffic."""

    def __init__(self, m: int, p: int, *, lam, mu, Lam,
                 dtype=jnp.float32,
                 decay: float = 1.0,
                 window: Optional[int] = None,
                 refit_every: int = 2048,
                 drift_threshold: float = 0.05,
                 max_refit_interval: Optional[int] = None,
                 lasso_iters: int = 400,
                 debias_iters: int = 600,
                 warm_lasso_iters: Optional[int] = None,
                 warm_debias_iters: Optional[int] = None,
                 chunk_n: Optional[int] = None,
                 mesh=None, data_axis: str = "data",
                 task_axis: str = "task"):
        if window is not None and mesh is not None:
            raise ValueError("sliding-window ingestion is host-only; "
                             "pass decay= for sharded non-stationarity")
        if window is not None and decay != 1.0:
            raise ValueError("decay and window are alternative forgetting "
                             "schemes; the window path aggregates its "
                             "chunks unweighted, so pass one or the other")
        self.m, self.p = m, p
        self.lam, self.mu, self.Lam = lam, mu, Lam
        self.decay = float(decay)
        self.lasso_iters = lasso_iters
        self.debias_iters = debias_iters
        self.warm_lasso_iters = warm_lasso_iters if warm_lasso_iters \
            is not None else max(lasso_iters // 4, 25)
        self.warm_debias_iters = warm_debias_iters if warm_debias_iters \
            is not None else max(debias_iters // 4, 25)
        self.refit_every = refit_every
        self.drift_threshold = float(drift_threshold)
        self.max_refit_interval = max_refit_interval \
            if max_refit_interval is not None else 16 * refit_every
        self.mesh, self.data_axis, self.task_axis = mesh, data_axis, task_axis
        # warm the kernel block-size cache for this workload's solve
        # shapes — and, when the expected chunk rows `chunk_n` are
        # known, for the rank-n ingest and logistic-gradient kernels —
        # before any jitted ingest/refit traces (no-op off-TPU)
        from repro.kernels.autotune import warmup_cache
        warmup_cache(m, p, chunk_n, dtype=dtype)
        self.state = init_stream_state(m, p, dtype)
        self.window = init_window(window, m, p, dtype) if window else None
        self._interval = refit_every
        self._since_refit = 0
        self.last_info: Optional[RefitInfo] = None

    # -- ingestion --------------------------------------------------------

    def ingest(self, X_batch: jnp.ndarray,
               y_batch: jnp.ndarray) -> Optional[RefitInfo]:
        """Fold one (m, n, p)/(m, n) minibatch in; maybe refit.

        Returns the `RefitInfo` when this chunk triggered a refit,
        None otherwise.

        The `stream.ingest` span times the host-side fold DISPATCH
        (the jitted fold is asynchronous — rows/sec headlines from it
        are an upper bound on sustained throughput); a triggered refit
        is timed by its own `stream.refit` span, not this one.
        """
        n = int(X_batch.shape[1])
        with obs.span("stream.ingest"):
            if self.window is not None:
                self.window = window_ingest(self.window, X_batch, y_batch)
            elif self.mesh is not None:
                self.state = ingest_sharded(self.state, X_batch, y_batch,
                                            self.mesh, decay=self.decay,
                                            data_axis=self.data_axis,
                                            task_axis=self.task_axis)
            else:
                self.state = ingest(self.state, X_batch, y_batch,
                                    decay=self.decay)
        obs.inc("stream.ingest.chunks")
        obs.inc("stream.ingest.rows", self.m * n)
        self._since_refit += n
        if self._since_refit >= self._interval:
            return self.refit()
        return None

    # -- refit policy -----------------------------------------------------

    def refit(self) -> RefitInfo:
        """Force a DSML refresh now and adapt the refit cadence.

        The `stream.refit` span is TRUE latency (unlike the async
        ingest span): the drift read forces `float(info.jaccard)`,
        which blocks on the refreshed model inside the span.
        """
        with obs.span("stream.refit"):
            if self.window is not None and int(self.window.seen) > 0:
                # an empty ring buffer (fresh service, or state restored
                # without its window) must not wipe the stats with zeros
                Sigmas, cs, counts = window_stats(self.window)
                self.state = self.state._replace(Sigmas=Sigmas, cs=cs,
                                                 counts=counts)
            warm = int(self.state.generation) > 0
            l_iters = self.warm_lasso_iters if warm else self.lasso_iters
            d_iters = self.warm_debias_iters if warm else self.debias_iters
            self.state, info = refit(self.state, self.lam, self.mu,
                                     self.Lam, lasso_iters=l_iters,
                                     debias_iters=d_iters, warm=warm)
            drift = 1.0 - float(info.jaccard)
            if warm and drift <= self.drift_threshold:
                self._interval = min(2 * self._interval,
                                     self.max_refit_interval)
            else:
                self._interval = self.refit_every
        obs.inc("stream.refit.count")
        obs.observe("stream.refit.jaccard", float(info.jaccard))
        obs.observe("stream.refit.support_size", float(info.support_size))
        obs.set_gauge("stream.generation", int(info.generation))
        obs.set_gauge("stream.refit.interval_samples", self._interval)
        self._since_refit = 0
        self.last_info = info
        return info

    # -- serving ----------------------------------------------------------

    def predict(self, X: jnp.ndarray) -> jnp.ndarray:
        """Scores under the current servable model.

        X (m, n, p) gives per-task designs -> (m, n); X (n, p) is one
        shared design scored by every task's estimate -> (m, n).

        The `stream.predict` span times the host-side dispatch (the
        jitted matmul is asynchronous), which is the admission latency
        a serving front would see.
        """
        with obs.span("stream.predict"):
            if X.ndim == 2:
                out = _predict_shared(self.state.beta_tilde, X)
            else:
                out = _predict_tasks(self.state.beta_tilde, X)
        obs.inc("stream.predict.requests")
        obs.inc("stream.predict.rows", int(X.shape[-2]))
        return out

    @property
    def generation(self) -> int:
        return int(self.state.generation)

    @property
    def samples_seen(self) -> float:
        """Effective per-task sample count (decayed if decay < 1)."""
        return float(jnp.max(self.state.counts))

    # -- persistence ------------------------------------------------------

    def _ckpt_tree(self):
        # window mode keeps the authoritative statistics in the ring
        # buffer, so it must round-trip alongside the state
        if self.window is not None:
            return {"state": self.state, "window": self.window}
        return {"state": self.state}

    def save(self, path: str) -> None:
        save_pytree(path, self._ckpt_tree())

    def load(self, path: str) -> None:
        """Restore a checkpointed state (shape/dtype-validated; a
        window-mode service restores its ring buffer too). Loading a
        window-mode checkpoint into a non-window service (or vice
        versa) raises rather than silently changing the forgetting
        semantics."""
        if self.window is None:
            import numpy as np
            fname = path if path.endswith(".npz") else path + ".npz"
            if any(k.startswith("window/") for k in np.load(fname).files):
                raise ValueError(
                    "checkpoint was saved by a window-mode service; "
                    "construct with window= to restore it")
        restored = restore_pytree(path, self._ckpt_tree())
        self.state = restored["state"]
        if self.window is not None:
            self.window = restored["window"]
        self._since_refit = 0
