"""StreamingDsmlService: the online DSML loop as a servable driver.

Ties the streaming pieces together around one `StreamState`:

    ingest loop     raw minibatches fold into the state (host path,
                    decayed, sliding-window, or SPMD over a data x task
                    mesh via `stream.accumulate`);
    guarded ingest  an `IngestGuard` in front of the fold quarantines
                    non-finite / magnitude-outlier chunks BEFORE they
                    can poison the irreversible `(Sigma, c)` statistics
                    (`stream/guard.py`; pass `guard=False` to opt out);
    refit policy    a refit runs every `refit_every` ingested samples;
                    when the refreshed support has not drifted
                    (jaccard >= 1 - drift_threshold) the interval
                    doubles, up to `max_refit_interval` — stationary
                    traffic converges to rare refits, a support shift
                    snaps the cadence back to the base rate;
    refit health    every candidate refit passes the `stream/health.py`
                    invariants (finite model, support sanity, KKT
                    residual ceiling) before it is adopted; a failing
                    candidate is ROLLED BACK — the service keeps
                    serving the last good generation, the retry waits
                    out a capped exponential backoff and runs with an
                    escalated iteration budget (DESIGN.md §15);
    warm starts     generation-0 refits run the full cold budget,
                    later ones warm-start both solves (lasso from
                    `beta_local`, debias from `Ms`) with the
                    `warm_*_iters` budgets (default: a quarter);
    serving         `predict` scores against ONE immutable
                    `ModelGeneration` snapshot captured per call (always
                    the last HEALTHY generation) — adoption installs a
                    new snapshot with a single atomic reference swap, so
                    a predict racing a refit can never observe a torn or
                    mixed-generation model; `stream/serve.py` builds the
                    async microbatched front on the same snapshots;
    persistence     `save`/`load` round-trip the state through
                    `checkpoint/io` (atomic npz; `load` validates
                    (m, p, dtype) compatibility before touching live
                    state), and `ckpt_dir=` upgrades persistence to the
                    crash-safe `CheckpointStore` — checksummed
                    manifest, retained generations, `restore()`
                    falling back past a corrupted head.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint.io import (
    CheckpointError, load_npz, npz_safe_dtype, restore_pytree, save_pytree,
)
from repro.checkpoint.manifest import CheckpointStore
from repro.stream.accumulate import ingest_sharded
from repro.stream.guard import IngestGuard, _guarded_fold
from repro.stream.health import RefitHealth, refit_health
from repro.stream.refit import RefitInfo, jaccard_support, refit
from repro.stream.serve import ModelGeneration
from repro.substrate import feed_chunk
from repro.stream.state import (
    StreamState, init_stream_state, init_window, ingest, window_ingest,
    window_stats,
)

# consecutive-failure escalation of the retry iteration budget is
# capped: past 2 failures more iterations stop being the cure and the
# backoff (waiting for more data) carries the recovery instead
MAX_ITER_ESCALATION = 4


@jax.jit
def _predict_tasks(beta_tilde: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("tnp,tp->tn", X, beta_tilde)


@jax.jit
def _predict_shared(beta_tilde: jnp.ndarray, X: jnp.ndarray) -> jnp.ndarray:
    return jnp.einsum("np,tp->tn", X, beta_tilde)


class StreamingDsmlService:
    """Online DSML over continuously arriving multi-task traffic.

    Thread-sharing contract (`_SYNC_POLICY`, checked by repro_lint
    RL4xx): all mutation — ingest/refit/rollback/load/restore — belongs
    to ONE driver thread; its public entry points are the `worker-only`
    roots below. Reader threads (predict, the serving front) touch
    only `_serving`, which is republished exclusively by whole-object
    atomic reference swap inside `publish_model` — so a reader can race
    any number of refits and never observe a torn model. `_refit_impl`
    is the fault-injection seam (repro.testing.faults) and is likewise
    swapped only by whole-reference assignment.
    """

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "state": "worker-only:ingest,refit,load,restore,save,"
                 "checkpoint,generation,samples_seen",
        "window": "worker-only:ingest,refit,load,restore,save,"
                  "checkpoint,generation,samples_seen",
        "_interval": "worker-only:ingest,refit,load,restore,save,"
                     "checkpoint,generation,samples_seen",
        "_since_refit": "worker-only:ingest,refit,load,restore,save,"
                        "checkpoint,generation,samples_seen",
        "_refit_failures": "worker-only:ingest,refit,load,restore,save,"
                           "checkpoint,generation,samples_seen",
        "rollbacks": "worker-only:ingest,refit,load,restore,save,"
                     "checkpoint,generation,samples_seen",
        "last_info": "worker-only:ingest,refit,load,restore,save,"
                     "checkpoint,generation,samples_seen",
        "last_health": "worker-only:ingest,refit,load,restore,save,"
                       "checkpoint,generation,samples_seen",
        "_refit_impl": "atomic-publish",
        "_serving": "atomic-publish:publish_model",
    }

    def __init__(self, m: int, p: int, *, lam, mu, Lam,
                 dtype=jnp.float32,
                 decay: float = 1.0,
                 window: Optional[int] = None,
                 refit_every: int = 2048,
                 drift_threshold: float = 0.05,
                 max_refit_interval: Optional[int] = None,
                 lasso_iters: int = 400,
                 debias_iters: int = 600,
                 warm_lasso_iters: Optional[int] = None,
                 warm_debias_iters: Optional[int] = None,
                 refit_tol: Optional[float] = None,
                 chunk_n: Optional[int] = None,
                 guard=True,
                 refit_health_checks: bool = True,
                 refit_kkt_ceiling: float = 1.0,
                 max_support: Optional[int] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_keep: int = 3,
                 checkpoint_on_refit: bool = True,
                 mesh=None, data_axis: str = "data",
                 task_axis: str = "task"):
        if window is not None and mesh is not None:
            raise ValueError("sliding-window ingestion is host-only; "
                             "pass decay= for sharded non-stationarity")
        if window is not None and decay != 1.0:
            raise ValueError("decay and window are alternative forgetting "
                             "schemes; the window path aggregates its "
                             "chunks unweighted, so pass one or the other")
        self.m, self.p = m, p
        self.dtype = dtype
        self.lam, self.mu, self.Lam = lam, mu, Lam
        self.decay = float(decay)
        self.lasso_iters = lasso_iters
        self.debias_iters = debias_iters
        self.warm_lasso_iters = warm_lasso_iters if warm_lasso_iters \
            is not None else max(lasso_iters // 4, 25)
        self.warm_debias_iters = warm_debias_iters if warm_debias_iters \
            is not None else max(debias_iters // 4, 25)
        # refit latency budget: with a tol, every iteration count above
        # becomes a CEILING — the solves early exit on their KKT
        # residuals, so a warm refit costs what the statistics drift
        # demands and the ceiling bounds the refit's worst-case latency
        self.refit_tol = refit_tol if refit_tol is None else float(refit_tol)
        self.refit_every = refit_every
        self.drift_threshold = float(drift_threshold)
        self.max_refit_interval = max_refit_interval \
            if max_refit_interval is not None else 16 * refit_every
        # guarded ingest: True -> default gate, False/None -> off, or an
        # IngestGuard instance for tuned thresholds
        if guard is True:
            self.guard: Optional[IngestGuard] = IngestGuard()
        elif guard is False or guard is None:
            self.guard = None
        else:
            self.guard = guard
        self.refit_health_checks = refit_health_checks
        self.refit_kkt_ceiling = float(refit_kkt_ceiling)
        self.max_support = max_support
        self.ckpt_store = CheckpointStore(ckpt_dir, keep=ckpt_keep) \
            if ckpt_dir is not None else None
        self.checkpoint_on_refit = checkpoint_on_refit
        self.mesh, self.data_axis, self.task_axis = mesh, data_axis, task_axis
        # warm the kernel block-size cache for this workload's solve
        # shapes — and, when the expected chunk rows `chunk_n` are
        # known, for the rank-n ingest and logistic-gradient kernels —
        # before any jitted ingest/refit traces (no-op off-TPU)
        from repro.kernels.autotune import warmup_cache
        warmup_cache(m, p, chunk_n, dtype=dtype)
        self.state = init_stream_state(m, p, dtype)
        self.window = init_window(window, m, p, dtype) if window else None
        self._interval = refit_every
        self._since_refit = 0
        self._refit_failures = 0     # consecutive rejected candidates
        self.rollbacks = 0           # total rejected candidates, ever
        self.last_info: Optional[RefitInfo] = None
        self.last_health: Optional[RefitHealth] = None
        # injectable refit seam: the fault-injection harness
        # (repro.testing.faults) swaps this to script divergence; the
        # production path never touches it
        self._refit_impl = refit
        # the published model: ONE immutable snapshot, replaced only by
        # whole-reference assignment (atomic under the GIL) at the
        # closed set of model-changing sites — adoption, load/restore,
        # and explicit publish_model(). predict never reads live state.
        self._serving: ModelGeneration = self.publish_model()

    # -- ingestion --------------------------------------------------------

    def ingest(self, X_batch: jnp.ndarray,
               y_batch: jnp.ndarray) -> Optional[RefitInfo]:
        """Fold one (m, n, p)/(m, n) minibatch in; maybe refit.

        Returns the `RefitInfo` when this chunk triggered a refit
        attempt, None otherwise (including when the guard quarantined
        the chunk — a rejected chunk neither folds nor advances the
        refit cadence, so `(Sigma, c)` stay bitwise unchanged).

        The `stream.ingest` span times the host-side fold DISPATCH
        (the jitted fold is asynchronous — rows/sec headlines from it
        are an upper bound on sustained throughput); a triggered refit
        is timed by its own `stream.refit` span, not this one.
        """
        # dense host path: probe fused into the fold dispatch (one
        # launch, one sync — the <2% overhead contract); window/sharded
        # paths — and a guard with an absolute max_abs ceiling, which
        # the fused statistics-derived probe cannot evaluate — probe
        # standalone in front of their folds
        fused = (self.guard is not None and self.window is None
                 and self.mesh is None and self.guard.max_abs is None)
        if self.guard is not None and not fused:
            ok, _reason = self.guard.admit(X_batch, y_batch)
            if not ok:
                obs.inc("stream.ingest.quarantined_chunks")
                return None
        n = int(X_batch.shape[1])
        with obs.span("stream.ingest"):
            if fused:
                folded, health = _guarded_fold(
                    self.state, X_batch, y_batch, self.decay)
                ok, _reason = self.guard.record(
                    np.asarray(health),
                    tuple(int(s) for s in X_batch.shape))
                if not ok:
                    # the speculative fold is discarded unassigned:
                    # (Sigma, c) stay bitwise the pre-chunk arrays
                    obs.inc("stream.ingest.quarantined_chunks")
                    return None
                self.state = folded
            elif self.window is not None:
                self.window = window_ingest(self.window, X_batch, y_batch)
            elif self.mesh is not None:
                # place the chunk in the accumulator's (task, data)
                # layout before the fold — per-device transfers through
                # the substrate feed, no gather, no resharding inside
                # the compiled worker
                Xd, yd = feed_chunk(X_batch, y_batch, self.mesh,
                                    data_axis=self.data_axis,
                                    task_axis=self.task_axis)
                self.state = ingest_sharded(self.state, Xd, yd,
                                            self.mesh, decay=self.decay,
                                            data_axis=self.data_axis,
                                            task_axis=self.task_axis)
            else:
                self.state = ingest(self.state, X_batch, y_batch,
                                    decay=self.decay)
        obs.inc("stream.ingest.chunks")
        obs.inc("stream.ingest.rows", self.m * n)
        self._since_refit += n
        if self._since_refit >= self._interval:
            return self.refit()
        return None

    # -- refit policy -----------------------------------------------------

    def refit(self) -> RefitInfo:
        """Attempt a DSML refresh now; adopt it only if healthy.

        A healthy candidate advances the generation and adapts the
        cadence exactly as before. An UNHEALTHY candidate (non-finite
        model, oversized support, KKT residual past the ceiling) is
        discarded: the service keeps serving the last good generation,
        the next attempt waits out a capped exponential backoff
        (base_interval * 2^failures, capped at `max_refit_interval`)
        and runs with an escalated iteration budget (cold budgets x
        2^failures, capped at x4). The returned `RefitInfo` then
        describes the KEPT state (unchanged generation, jaccard 1.0).

        The `stream.refit` span is TRUE latency (unlike the async
        ingest span): the health verdict and drift read block on the
        refreshed model inside the span.
        """
        with obs.span("stream.refit"):
            if self.window is not None and int(self.window.seen) > 0:
                # an empty ring buffer (fresh service, or state restored
                # without its window) must not wipe the stats with zeros
                Sigmas, cs, counts = window_stats(self.window)
                self.state = self.state._replace(Sigmas=Sigmas, cs=cs,
                                                 counts=counts)
            warm = int(self.state.generation) > 0
            if self._refit_failures == 0:
                l_iters = self.warm_lasso_iters if warm else self.lasso_iters
                d_iters = self.warm_debias_iters if warm \
                    else self.debias_iters
            else:
                # retry after rollback: escalated budget, warm-started
                # from the last GOOD generation (the rejected candidate
                # never touched the state)
                esc = min(2 ** self._refit_failures, MAX_ITER_ESCALATION)
                l_iters = self.lasso_iters * esc
                d_iters = self.debias_iters * esc
            candidate, info = self._refit_impl(
                self.state, self.lam, self.mu, self.Lam,
                lasso_iters=l_iters, debias_iters=d_iters, warm=warm,
                tol=self.refit_tol)
            if self.refit_health_checks:
                health = refit_health(candidate, self.lam,
                                      kkt_ceiling=self.refit_kkt_ceiling,
                                      max_support=self.max_support)
            else:
                health = RefitHealth(True, None, float("nan"), -1)
            self.last_health = health
            if not health.healthy:
                return self._rollback(health)
            # adoption = two atomic reference swaps: the live state for
            # the ingest loop, then the published snapshot for readers.
            # A concurrent predict holds whichever snapshot it grabbed —
            # entirely old or entirely new, never a mixture.
            self.state = candidate
            self.publish_model()
            drift = 1.0 - float(info.jaccard)
            if warm and self._refit_failures == 0 \
                    and drift <= self.drift_threshold:
                self._interval = min(2 * self._interval,
                                     self.max_refit_interval)
            else:
                self._interval = self.refit_every
            self._refit_failures = 0
        obs.inc("stream.refit.count")
        obs.observe("stream.refit.jaccard", float(info.jaccard))
        obs.observe("stream.refit.support_size", float(info.support_size))
        obs.observe("stream.refit.kkt_residual", health.kkt_residual)
        if info.lasso_iters_run is not None:
            obs.observe("stream.refit.lasso_iters", int(info.lasso_iters_run))
            obs.observe("stream.refit.debias_iters",
                        int(info.debias_iters_run))
        obs.set_gauge("stream.generation", int(info.generation))
        obs.set_gauge("stream.refit.interval_samples", self._interval)
        obs.set_gauge("stream.refit.failures", 0)
        self._since_refit = 0
        self.last_info = info
        if self.ckpt_store is not None and self.checkpoint_on_refit:
            self.checkpoint()
        return info

    def _rollback(self, health: RefitHealth) -> RefitInfo:
        """Discard an unhealthy candidate; keep serving the last good
        generation and schedule the escalated retry."""
        self._refit_failures += 1
        self.rollbacks += 1
        self._interval = min(self.refit_every * 2 ** self._refit_failures,
                             self.max_refit_interval)
        self._since_refit = 0
        obs.inc("stream.refit.rejected", reason=health.reason)
        obs.set_gauge("stream.refit.failures", self._refit_failures)
        obs.set_gauge("stream.refit.interval_samples", self._interval)
        info = RefitInfo(
            jaccard=jnp.asarray(1.0, self.state.cs.dtype),
            support_size=jnp.sum(self.state.support).astype(jnp.int32),
            generation=self.state.generation)
        self.last_info = info
        return info

    # -- serving ----------------------------------------------------------

    def publish_model(self) -> ModelGeneration:
        """Snapshot the current model into a fresh `ModelGeneration` and
        install it as the published snapshot (one reference assignment —
        atomic under the GIL). Called automatically at every site where
        the model can change (adoption, load/restore, construction);
        code that mutates `state` directly must call it afterwards."""
        st = self.state  # ONE read: the snapshot's fields stay coherent
        snap = ModelGeneration(beta_tilde=st.beta_tilde,
                               support=st.support,
                               generation=int(st.generation))
        self._serving = snap
        return snap

    def serving(self) -> ModelGeneration:
        """The published model, as one immutable snapshot. Hold it for
        as long as a unit of work needs model coherence (a predict
        call, a serving-front microbatch): refits adopting a new
        generation swap the reference under you without ever mutating
        the snapshot you hold."""
        return self._serving

    def _normalize_predict_input(self, X):
        """The predict input contract, enforced in one place.

        (p,)       one shared-design row       -> (1, p), shared
        (n, p)     shared design, n rows       -> unchanged, shared
        (m, n, p)  per-task designs            -> unchanged, per-task

        Returns `(X, shared)`. Anything else — wrong feature count,
        wrong task count, other ranks — raises instead of silently
        broadcasting (the old path fed rank-1 inputs straight to the
        einsum and miscounted their rows as `p`)."""
        X = jnp.asarray(X)
        if X.ndim == 1:
            if X.shape[0] != self.p:
                raise ValueError(f"rank-1 predict input must be one "
                                 f"({self.p},) row; got {X.shape}")
            return X.reshape(1, self.p), True
        if X.ndim == 2:
            if X.shape[1] != self.p:
                raise ValueError(f"shared design must be (n, {self.p}); "
                                 f"got {X.shape}")
            return X, True
        if X.ndim == 3:
            if X.shape[0] != self.m or X.shape[2] != self.p:
                raise ValueError(f"per-task designs must be "
                                 f"({self.m}, n, {self.p}); got {X.shape}")
            return X, False
        raise ValueError(f"predict input must be rank 1, 2, or 3; "
                         f"got rank {X.ndim} {X.shape}")

    def predict(self, X: jnp.ndarray, *,
                return_generation: bool = False) -> jnp.ndarray:
        """Scores under the published model.

        X (m, n, p) gives per-task designs -> (m, n); X (n, p) is one
        shared design scored by every task's estimate -> (m, n); a
        single row (p,) is scored as a 1-row shared design -> (m, 1).

        Each call captures ONE `ModelGeneration` snapshot and scores
        the whole input against it — a refit adopting (or rolling
        back) mid-call cannot tear the model out from under the
        einsum. `return_generation=True` also returns the generation
        that scored, so callers can prove which model answered.

        The `stream.predict` span times the host-side dispatch (the
        jitted matmul is asynchronous), which is the admission latency
        a serving front would see.
        """
        X, shared = self._normalize_predict_input(X)
        snap = self.serving()
        with obs.span("stream.predict"):
            if shared:
                out = _predict_shared(snap.beta_tilde, X)
            else:
                out = _predict_tasks(snap.beta_tilde, X)
        obs.inc("stream.predict.requests")
        obs.inc("stream.predict.rows", int(X.shape[-2]))
        return (out, snap.generation) if return_generation else out

    @property
    def generation(self) -> int:
        return int(self.state.generation)

    @property
    def samples_seen(self) -> float:
        """Effective per-task sample count (decayed if decay < 1)."""
        return float(jnp.max(self.state.counts))

    # -- persistence ------------------------------------------------------

    def _ckpt_tree(self):
        # window mode keeps the authoritative statistics in the ring
        # buffer, so it must round-trip alongside the state
        if self.window is not None:
            return {"state": self.state, "window": self.window}
        return {"state": self.state}

    def save(self, path: str) -> None:
        """Atomic single-file snapshot (tmp + fsync + rename); see
        `checkpoint()` for the retained-generation store."""
        save_pytree(path, self._ckpt_tree())

    def _validate_ckpt_compat(self, data, where: str) -> None:
        """Reject a checkpoint that was not produced by a service of
        this (m, p, dtype) BEFORE any live state is overwritten."""
        key = "state/Sigmas"
        if key not in data.files:
            raise CheckpointError(
                f"{where} is not a StreamingDsmlService checkpoint "
                f"(no '{key}' leaf; found e.g. {list(data.files)[:3]})")
        arr = data[key]
        want = (self.m, self.p, self.p)
        if arr.shape != want:
            raise CheckpointError(
                f"{where} was saved by an incompatible service: "
                f"state/Sigmas shape {arr.shape} != {want} "
                f"(m={self.m}, p={self.p})")
        exp = npz_safe_dtype(self.dtype)
        if arr.dtype != exp:
            raise CheckpointError(
                f"{where} dtype {arr.dtype} != this service's {exp}")

    def load(self, path: str) -> None:
        """Restore a checkpointed state. The checkpoint's (m, p, dtype)
        and window-ness are validated against this service BEFORE live
        state is overwritten, so a wrong-path load cannot clobber a
        serving model. Loading a window-mode checkpoint into a
        non-window service (or vice versa) raises rather than silently
        changing the forgetting semantics."""
        fname = path if path.endswith(".npz") else path + ".npz"
        data = load_npz(fname)
        has_window = any(k.startswith("window/") for k in data.files)
        if self.window is None and has_window:
            raise ValueError(
                "checkpoint was saved by a window-mode service; "
                "construct with window= to restore it")
        if self.window is not None and not has_window:
            raise ValueError(
                "checkpoint was saved by a non-window service; its ring "
                "buffer is absent — construct without window= to "
                "restore it")
        self._validate_ckpt_compat(data, f"checkpoint '{fname}'")
        restored = restore_pytree(path, self._ckpt_tree())
        self.state = restored["state"]
        if self.window is not None:
            self.window = restored["window"]
        self._since_refit = 0
        self._refit_failures = 0
        self.publish_model()

    def checkpoint(self) -> Optional[str]:
        """Persist the current generation to the crash-safe store
        (requires `ckpt_dir=`). Returns the payload path."""
        if self.ckpt_store is None:
            raise ValueError("no ckpt_dir configured on this service")
        path = self.ckpt_store.save(self._ckpt_tree(), self.generation)
        return path

    def restore(self) -> int:
        """Load the newest HEALTHY retained generation from the store,
        falling back past corrupted checkpoints (requires `ckpt_dir=`).
        Returns the restored generation."""
        if self.ckpt_store is None:
            raise ValueError("no ckpt_dir configured on this service")
        tree, generation = self.ckpt_store.load(self._ckpt_tree())
        self.state = tree["state"]
        if self.window is not None:
            self.window = tree["window"]
        self._since_refit = 0
        self._refit_failures = 0
        self.publish_model()
        obs.set_gauge("stream.generation", self.generation)
        return generation
