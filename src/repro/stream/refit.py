"""Incremental DSML refresh from streaming sufficient statistics.

A refit re-runs Algorithm 1's compute (local lasso -> debias ->
group-threshold) on the state's current `(Sigma, c)` — identical math
to `dsml_fit` on the data the state has absorbed, but with the step-1
FISTA warm-started from the previous solution. Warm starts matter
because consecutive refits see nearly identical statistics: the
iterates start at (numerically) the previous optimum, so a fraction of
the cold iteration budget reaches the same tolerance — that is the
warm/cold gap `benchmarks/stream_bench.py` measures.

`RefitInfo.jaccard` reports support drift against the previous
generation so callers can refit lazily: an unchanged support (jaccard
== 1) means the served model has not moved and the next refit can wait.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import (
    debias_batched, inverse_hessian_batched, power_iteration_batched,
    scaled_identity_m0, solve_lasso_eq2, solve_logistic_lasso_batched,
)
from repro.core.logistic import debias_logistic_batched
from repro.core.prox import support_from_rows
from repro.stream.state import StreamState


class RefitInfo(NamedTuple):
    jaccard: jnp.ndarray        # () similarity of new vs previous support
    support_size: jnp.ndarray   # () int32 |S_hat| after thresholding
    generation: jnp.ndarray     # () int32 generation of the NEW state
    # iterations the two solves actually ran (== the ceilings unless a
    # tol was set); None on paths that never count (e.g. rollback infos)
    lasso_iters_run: jnp.ndarray | None = None
    debias_iters_run: jnp.ndarray | None = None


def jaccard_support(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """|a & b| / |a | b|, defined as 1.0 when both supports are empty."""
    inter = jnp.sum(a & b)
    union = jnp.sum(a | b)
    return jnp.where(union > 0, inter / jnp.maximum(union, 1), 1.0)


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters", "warm"))
def refit(state: StreamState, lam, mu, Lam, lasso_iters: int = 400,
          debias_iters: int = 600, warm: bool = True,
          tol=None) -> Tuple[StreamState, RefitInfo]:
    """One DSML refresh on the state's statistics.

    Returns the new state (updated beta/M/support, generation + 1) and
    a `RefitInfo`. With `warm=True` both solves restart from the
    previous generation: the lasso from `beta_local` (an empty state's
    zeros make the first warm refit identical to a cold one) and the
    debias M solve from `Ms` (generation 0 falls back to the engine's
    scaled-identity start, selected under jit via the traced
    generation).

    `tol=` turns the iteration counts into CEILINGS: both solves early
    exit on their KKT residuals, so a warm refit under a tol costs only
    the iterations the statistics drift actually demands — the latency
    budget the serving front relies on to keep refits off the predict
    path. The iterations run come back on the info
    (`lasso_iters_run`/`debias_iters_run`).
    """
    beta0 = state.beta_local if warm else None
    M0 = None
    if warm:
        M0 = jnp.where(state.generation > 0, state.Ms,
                       scaled_identity_m0(state.Sigmas))
    lam_max = power_iteration_batched(state.Sigmas)
    beta_hat, lasso_run = solve_lasso_eq2(
        state.Sigmas, state.cs, lam, iters=lasso_iters, beta0=beta0,
        lam_max=lam_max, tol=tol, return_iters=True)
    Ms, debias_run = inverse_hessian_batched(
        state.Sigmas, mu, iters=debias_iters, M0=M0, lam_max=lam_max,
        tol=tol, return_iters=True)
    beta_u = debias_batched(state.Sigmas, state.cs, beta_hat, Ms)
    support = support_from_rows(beta_u.T, Lam)
    beta_tilde = beta_u * support[None, :]
    new_state = state._replace(
        beta_local=beta_hat, Ms=Ms, beta_u=beta_u, beta_tilde=beta_tilde,
        support=support, generation=state.generation + 1)
    info = RefitInfo(
        jaccard=jaccard_support(support, state.support).astype(state.cs.dtype),
        support_size=jnp.sum(support).astype(jnp.int32),
        generation=new_state.generation,
        lasso_iters_run=jnp.asarray(lasso_run, jnp.int32),
        debias_iters_run=jnp.asarray(debias_run, jnp.int32))
    return new_state, info


@partial(jax.jit, static_argnames=("lasso_iters", "debias_iters", "warm"))
def refit_logistic(state: StreamState, Xs: jnp.ndarray, ys: jnp.ndarray,
                   lam, mu, Lam, lasso_iters: int = 600,
                   debias_iters: int = 600,
                   warm: bool = True) -> Tuple[StreamState, RefitInfo]:
    """One Section-4 (classification) DSML refresh, warm-started from
    the previous generation exactly like the regression `refit`.

    The logistic loss is not a function of the state's `(Sigma, c)`
    statistics, so the gradient re-touches a retained raw window
    `Xs (m, n, p)` / `ys (m, n) in {-1, +1}` — but the state still
    carries everything that makes consecutive refits cheap: with
    `warm=True` the batched l1-logistic solve restarts from
    `beta_local` and the weighted-Hessian debias solve from the
    previous `Ms` (generation 0 falls back to the engine's
    scaled-identity start, selected under jit via the traced
    generation). The state's regression statistics fields are left
    untouched; the model fields (`beta_local`, `Ms`, `beta_u`,
    `beta_tilde`, `support`, `generation`) advance one generation.
    """
    beta0 = state.beta_local if warm else None
    beta_hat = solve_logistic_lasso_batched(Xs, ys, lam, iters=lasso_iters,
                                            beta0=beta0)
    beta_u, Ms = debias_logistic_batched(
        Xs, ys, beta_hat, mu, iters=debias_iters,
        M0=state.Ms if warm else None,
        M0_valid=(state.generation > 0) if warm else None)
    support = support_from_rows(beta_u.T, Lam)
    beta_tilde = beta_u * support[None, :]
    new_state = state._replace(
        beta_local=beta_hat, Ms=Ms, beta_u=beta_u, beta_tilde=beta_tilde,
        support=support, generation=state.generation + 1)
    info = RefitInfo(
        jaccard=jaccard_support(support, state.support).astype(state.cs.dtype),
        support_size=jnp.sum(support).astype(jnp.int32),
        generation=new_state.generation)
    return new_state, info
