"""Serving front for the streaming DSML service: atomic model
generations and an async microbatched predict path (DESIGN.md §16).

Two pieces, separable on purpose:

* **`ModelGeneration`** — the immutable unit of model publication. A
  snapshot of exactly the fields predict needs (`beta_tilde`, the
  support mask, and the generation stamped as a PYTHON int at publish
  time), built from ONE read of the service's state. The service
  publishes a new snapshot only when the model can actually have
  changed (refit adoption, checkpoint restore, construction) and
  installs it with a single reference assignment — atomic under the
  GIL — so a reader never observes a torn `(beta_tilde, generation)`
  pair no matter how refits interleave. Readers hold whatever snapshot
  they grabbed for as long as they need it; adoption never blocks
  them and they never block adoption (double buffering by immutability
  instead of locks).

* **`ServingFront`** — the admission/microbatching layer. Callers
  `submit()` single rows (or small row blocks) of the SHARED-design
  predict contract and get a future; a daemon worker drains the queue
  into a microbatch (up to `max_batch` rows, waiting at most
  `max_delay_ms` for stragglers), pads it to a power-of-two row bucket
  (bounded set of compiled shapes, the same trick the token-serving
  engine uses for its KV caches), and issues ONE `_predict_shared`
  dispatch against ONE `ModelGeneration` for the whole batch. Every
  result carries the generation that scored it, so a caller can prove
  batch-mates were never mixed across a refit.

Telemetry (all eager, worker-thread side — never under jit, RL108):
`serve.queue_depth` gauge at each drain, `serve.batch_fill` and
`serve.batch_rows` histograms, a `serve.batch` span around the
dispatch, `serve.request_ms` per-request enqueue-to-result latency
(p50/p99 via `obs.hist_quantiles`), and `serve.requests` / `serve.rows`
/ `serve.batches` / `serve.errors` counters.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from typing import List, NamedTuple, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro import obs

# microbatches are padded up to a power-of-two row count so the jitted
# predict sees a small closed set of shapes (1 compile per bucket), with
# a floor so tiny batches don't each mint a shape
MIN_BUCKET_ROWS = 8


class ModelGeneration(NamedTuple):
    """Immutable published model: everything predict reads, captured
    from one state snapshot. `generation` is a host int (stamped once,
    at publish) so serving-side bookkeeping never syncs on the device
    stream."""
    beta_tilde: jnp.ndarray      # (m, p) thresholded debiased estimates
    support: jnp.ndarray         # (p,) shared support mask
    generation: int


class ServeResult(NamedTuple):
    """Scores for one request plus the generation that produced them —
    `scores[t, i]` is task t's score for the request's row i."""
    scores: np.ndarray           # (m, rows)
    generation: int


def bucket_rows(rows: int, min_bucket: int = MIN_BUCKET_ROWS) -> int:
    """Smallest power-of-two >= rows (floored at `min_bucket`) — the
    padded row count a microbatch compiles at."""
    if rows < 1:
        raise ValueError(f"microbatch needs >= 1 row, got {rows}")
    b = min_bucket
    while b < rows:
        b *= 2
    return b


class _Request(NamedTuple):
    X: np.ndarray                # (rows, p) normalized shared design
    future: Future
    t_enqueue: float             # perf_counter seconds at admission


class ServingFront:
    """Async microbatched predict over a `StreamingDsmlService`.

        front = ServingFront(svc, max_batch=64, max_delay_ms=2.0)
        front.start()
        fut = front.submit(x_row)          # (p,) or (rows, p)
        res = fut.result()                 # ServeResult
        front.stop()

    The worker never touches the service's mutable fields — it reads
    one published `ModelGeneration` per microbatch via
    `svc.serving()`, so ingest/refit on other threads proceed
    untouched and every result in a batch is scored by the same
    generation. `predict(x)` is the synchronous convenience wrapper
    (submit + wait). The front is also a context manager.

    Lifecycle contract (`_SYNC_POLICY`, checked by repro_lint RL4xx):
    `start()`/`stop()` are driver-thread calls. Each worker owns its
    OWN stop event (passed at spawn, never read back through `self`),
    so a timed-out `stop()` followed by `start()` can never hand a
    half-stopped worker a cleared flag. `stop()` returns False and
    touches nothing when the worker outlives the join timeout — the
    live worker still owns the queue, the carry slot, and every
    admitted future; `_fail_pending` runs only after thread death
    proves exclusive ownership transferred back.
    """

    _SYNC_POLICY = {
        "*": "immutable-after-init",
        "_worker": "atomic-publish:start,stop",
        "_stop": "atomic-publish:start",
        "_carry": "worker-only:_run,_fail_pending",
    }

    def __init__(self, service, *, max_batch: int = 64,
                 max_delay_ms: float = 2.0,
                 min_bucket: int = MIN_BUCKET_ROWS,
                 poll_s: float = 0.1):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {max_batch}")
        self.service = service
        self.max_batch = int(max_batch)
        self.max_delay_s = float(max_delay_ms) / 1e3
        self.min_bucket = int(min_bucket)
        self.poll_s = float(poll_s)  # idle wake cadence of the worker
        self._q: "queue.Queue[Optional[_Request]]" = queue.Queue()
        self._carry: Optional[_Request] = None  # overflow from last drain
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "ServingFront":
        w = self._worker
        if w is not None:
            if w.is_alive() and not self._stop.is_set():
                return self
            # a previous stop() timed out (or the worker crashed): wait
            # the old worker out for real before spawning a new one, so
            # two workers never race on the same queue
            w.join()
            self._fail_pending()
        stop = threading.Event()
        worker = threading.Thread(
            target=self._run, args=(stop,), name="repro-serving-front",
            daemon=True)
        self._stop = stop
        self._worker = worker
        worker.start()
        return self

    def stop(self, timeout: float = 5.0) -> bool:
        """Drain-and-stop: already-admitted requests still resolve (the
        worker sweeps the queue before exiting). Returns True once the
        worker is confirmed dead; False when it outlived `timeout`, in
        which case NOTHING is reclaimed — the worker still owns the
        queue and every pending future, and a later stop()/start()
        waits it out."""
        w = self._worker
        if w is None:
            return True
        self._stop.set()
        self._q.put(None)            # wake the worker out of its drain
        w.join(timeout)
        if w.is_alive():
            return False
        self._worker = None
        self._fail_pending()
        return True

    def _fail_pending(self) -> None:
        """Fail anything admitted after the dead worker's final sweep.
        Callers must have proven the worker dead (join() returned and
        is_alive() is False) — thread death is the happens-before edge
        that makes this single-owner code."""
        leftovers: List[Optional[_Request]] = []
        if self._carry is not None:
            leftovers.append(self._carry)
            self._carry = None
        while True:
            try:
                leftovers.append(self._q.get_nowait())
            except queue.Empty:
                break
        for req in leftovers:
            if req is not None and not req.future.done():
                req.future.set_exception(
                    RuntimeError("serving front stopped"))

    def __enter__(self) -> "ServingFront":
        return self.start()

    def __exit__(self, *exc) -> bool:
        self.stop()
        return False

    # -- admission --------------------------------------------------------

    def submit(self, x) -> Future:
        """Admit one shared-design request: x (p,) is one row, (rows, p)
        a small block. Returns a `Future[ServeResult]`."""
        w = self._worker
        if w is None or not w.is_alive():
            raise RuntimeError("serving front is not running "
                               "(call start() or use as a context manager)")
        p = self.service.p
        X = np.asarray(x)
        if X.ndim == 1:
            X = X.reshape(1, -1)
        if X.ndim != 2 or X.shape[-1] != p:
            raise ValueError(f"request must be (p,) or (rows, p) with "
                             f"p={p}; got shape {np.asarray(x).shape}")
        if X.shape[0] > self.max_batch:
            raise ValueError(f"request rows {X.shape[0]} exceed "
                             f"max_batch={self.max_batch}; split it")
        fut: Future = Future()
        self._q.put(_Request(X, fut, time.perf_counter()))
        return fut

    def predict(self, x, timeout: Optional[float] = None) -> ServeResult:
        """Synchronous submit + wait."""
        return self.submit(x).result(timeout)

    # -- the worker -------------------------------------------------------

    def _drain(self) -> List[_Request]:
        """Block for the first request, then gather stragglers until the
        batch is full or `max_delay_ms` has passed since admission of
        the first — the classic admission-latency/batch-fill tradeoff
        knob."""
        if self._carry is not None:
            first, self._carry = self._carry, None
        else:
            try:
                first = self._q.get(timeout=self.poll_s)
            except queue.Empty:
                return []
            if first is None:
                return []
        obs.set_gauge("serve.queue_depth", self._q.qsize())
        batch = [first]
        rows = first.X.shape[0]
        deadline = time.perf_counter() + self.max_delay_s
        while rows < self.max_batch:
            wait = deadline - time.perf_counter()
            if wait <= 0:
                break
            try:
                req = self._q.get(timeout=wait)
            except queue.Empty:
                break
            if req is None:
                break
            if rows + req.X.shape[0] > self.max_batch:
                # does not fit: carried (in order) to lead the next batch
                self._carry = req
                break
            batch.append(req)
            rows += req.X.shape[0]
        return batch

    def _process(self, batch: Sequence[_Request]) -> None:
        """Score one microbatch with ONE dispatch against ONE published
        generation; deterministic and thread-free so tests can call it
        directly on hand-built requests."""
        from repro.stream.service import _predict_shared
        rows = sum(req.X.shape[0] for req in batch)
        snap: ModelGeneration = self.service.serving()
        padded = bucket_rows(rows, self.min_bucket)
        X = np.zeros((padded, batch[0].X.shape[1]),
                     dtype=snap.beta_tilde.dtype)
        off = 0
        for req in batch:
            X[off:off + req.X.shape[0]] = req.X
            off += req.X.shape[0]
        with obs.span("serve.batch", rows=rows, padded=padded):
            scores = np.asarray(
                _predict_shared(snap.beta_tilde, jnp.asarray(X)))
        t_done = time.perf_counter()
        off = 0
        for req in batch:
            n_i = req.X.shape[0]
            req.future.set_result(ServeResult(
                scores=scores[:, off:off + n_i],
                generation=snap.generation))
            off += n_i
            obs.observe("serve.request_ms",
                        (t_done - req.t_enqueue) * 1e3)
        obs.inc("serve.batches")
        obs.inc("serve.requests", len(batch))
        obs.inc("serve.rows", rows)
        obs.observe("serve.batch_rows", rows)
        obs.observe("serve.batch_fill", rows / self.max_batch)

    def _drain_remaining(self) -> List[_Request]:
        """Non-blocking gather for the worker's final sweep: carry slot
        first, then whatever is already queued, skipping stop
        sentinels, respecting max_batch (overflow re-parks in the
        carry for the next sweep iteration)."""
        batch: List[_Request] = []
        rows = 0
        if self._carry is not None:
            first, self._carry = self._carry, None
            batch.append(first)
            rows = first.X.shape[0]
        while rows < self.max_batch:
            try:
                req = self._q.get_nowait()
            except queue.Empty:
                break
            if req is None:
                continue
            if rows + req.X.shape[0] > self.max_batch:
                self._carry = req
                break
            batch.append(req)
            rows += req.X.shape[0]
        return batch

    def _process_safe(self, batch: Sequence[_Request]) -> None:
        try:
            self._process(batch)
        except Exception as e:  # noqa: BLE001 - recorded + propagated
            # a poisoned batch must not kill the worker: the error
            # goes to the batch's callers (their futures) and to
            # telemetry, and the loop keeps serving
            obs.inc("serve.errors", kind=type(e).__name__)
            for req in batch:
                if not req.future.done():
                    req.future.set_exception(e)

    def _run(self, stop: threading.Event) -> None:
        # `stop` is THIS worker's own event, bound at spawn: the worker
        # never reads self._stop, so a later start() publishing a fresh
        # event cannot un-stop a half-stopped worker
        while not stop.is_set():
            batch = self._drain()
            if batch:
                self._process_safe(batch)
        # final sweep: everything admitted before the stop still
        # resolves (drain-and-stop), batch by batch
        while True:
            batch = self._drain_remaining()
            if not batch:
                break
            self._process_safe(batch)

    # -- introspection ----------------------------------------------------

    def latency_quantiles(self, qs=(0.5, 0.99)) -> Optional[dict]:
        """Windowed request-latency quantiles (ms) from telemetry, None
        before any request resolved (or with obs disabled)."""
        return obs.hist_quantiles("serve.request_ms", qs)
