"""Guarded ingest: the validation gate in front of `(Sigma, c)`.

The streaming state's statistics are additive and *irreversible*: once
a chunk folds into the running `(Sigma, c)` means there is no inverse
update that removes it (the decayed/windowed variants only forget
slowly). A single NaN row therefore poisons every future refit, and a
fat-fingered 1e12 feature swamps the covariance for as long as the
decay horizon. `IngestGuard` rejects such chunks *before* the fold:

* **non-finite** — any NaN/Inf in X or y quarantines the chunk;
* **magnitude** — an optional absolute ceiling on max|x| (off by
  default: scale is workload-specific);
* **outlier** — a relative gate: once `warmup_chunks` chunks have been
  accepted, a chunk whose RMS exceeds `outlier_factor` x the
  exponential moving average RMS of accepted traffic is quarantined.
  The reference scale only learns from *accepted* chunks, so a burst
  of garbage cannot drag the gate open.

Overhead model (DESIGN.md §15): the health probe is ONE fused jitted
reduction over the chunk — O(m·n·p) element reads pulled to the host
as three scalars — in front of a fold that does O(m·n·p²) MACs; the
relative cost is ~1/p and `benchmarks/check_regression.py` gates the
guarded path at <2% of unguarded ingest. The probe does force a device
sync per chunk (the admission *decision* is a host branch), which is
the honest price of refusing to fold a chunk you have not looked at.

Rejected chunks land in a bounded quarantine ledger (newest
`ledger_capacity` records; older ones drop with a counter, never
unbounded growth) and are counted per reason under
`stream.quarantine{reason}`.
"""
from __future__ import annotations

from collections import deque
from typing import Deque, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.stream.state import ingest_stats, sufficient_stats


class QuarantineRecord(NamedTuple):
    seq: int                 # ingest sequence number of the rejected chunk
    reason: str              # "nonfinite" | "magnitude" | "outlier"
    shape: Tuple[int, ...]   # (m, n, p) of the offending chunk
    stat: float              # the statistic that tripped the gate
    threshold: float         # the bound it violated


def _chunk_health(X: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """[all_finite, rms, max_abs] as a (3,) f32 — two reductions over
    the raw chunk, cheap enough to ride inside the fold's own dispatch.

    NaN and Inf both propagate through `max(|.|)`, so the single
    `isfinite(max_abs)` scalar covers the whole finiteness check with
    no extra pass. A non-finite chunk's rms may itself be NaN; every
    consumer checks the finite flag (or compares NaN-safely) first.
    """
    rms = jnp.sqrt(jnp.mean(jnp.square(X.astype(jnp.float32))))
    max_abs = jnp.maximum(jnp.max(jnp.abs(X)),
                          jnp.max(jnp.abs(y))).astype(jnp.float32)
    finite = jnp.isfinite(max_abs)
    return jnp.stack([finite.astype(jnp.float32), rms, max_abs])


_batch_health = jax.jit(_chunk_health)


@jax.jit
def _guarded_fold(state, X: jnp.ndarray, y: jnp.ndarray, decay):
    """Speculative fold + health derived from the fold's OWN chunk
    statistics, one dispatch, O(m·p) probe cost.

    The host classifies the pulled health and simply keeps the old
    state object when the chunk is rejected — the folded (possibly
    poisoned) state is discarded unassigned, so rejection is bitwise
    exact by construction (no select pass; a device-side mask of the
    running mean would re-round it anyway).

    The health costs next to nothing because it reads the chunk
    statistics the fold computes regardless, never the raw chunk (an
    explicit O(m·n·p) reduction over X measured 8-20% of the fold on
    CPU — XLA's scalar reduce loop against Eigen's threaded matmul):

    * `diag(Sigma_b)[t, j] = mean_i X[t,i,j]^2` — every element of X
      appears squared in its own diagonal entry, so one NaN/Inf
      anywhere makes `sum(diag)` non-finite, and
      `sqrt(mean(diag)) == rms(X)` exactly;
    * `c_b = X^T y / n` catches the y side: a non-finite y[t, i]
      reaches every c_b[t, :] entry it touches (IEEE `0 * Inf = NaN`,
      so even an all-zero X row cannot launder it).

    max|x| is NOT derivable from the fold's statistics, so the fused
    path carries no absolute-magnitude verdict (health[2] = NaN); a
    guard configured with `max_abs=` routes through the standalone
    `admit` probe instead and pays its separate dispatch.
    """
    n = X.shape[1]
    Sigma_b, c_b = sufficient_stats(X, y)
    count_b = jnp.full(state.counts.shape, n, state.counts.dtype)
    folded = ingest_stats(state, Sigma_b, c_b, count_b, decay)
    diag = jnp.diagonal(Sigma_b, axis1=1, axis2=2)
    ss, cs_ss = jnp.sum(diag), jnp.sum(jnp.square(c_b))
    finite = jnp.isfinite(ss) & jnp.isfinite(cs_ss)
    rms = jnp.sqrt(jnp.mean(diag))
    health = jnp.stack([finite.astype(jnp.float32),
                        rms.astype(jnp.float32),
                        jnp.full((), jnp.nan, jnp.float32)])
    return folded, health


class IngestGuard:
    """Admission gate for streaming minibatches.

    `admit(X, y)` returns `(ok, reason)`; on `ok=False` the caller must
    not fold the chunk (the service path simply skips `ingest`, leaving
    `(Sigma, c)` bitwise untouched). The guard is host-side state — it
    is not part of the checkpointed pytree; a restarted service starts
    with a fresh (warming-up) reference scale.
    """

    def __init__(self, *, max_abs: Optional[float] = None,
                 outlier_factor: Optional[float] = 10.0,
                 warmup_chunks: int = 5,
                 ema_decay: float = 0.99,
                 ledger_capacity: int = 256):
        if outlier_factor is not None and outlier_factor <= 1.0:
            raise ValueError(f"outlier_factor must be > 1 (or None to "
                             f"disable), got {outlier_factor}")
        if not 0.0 < ema_decay < 1.0:
            raise ValueError(f"ema_decay must be in (0, 1), got {ema_decay}")
        self.max_abs = max_abs
        self.outlier_factor = outlier_factor
        self.warmup_chunks = int(warmup_chunks)
        self.ema_decay = float(ema_decay)
        self.ledger: Deque[QuarantineRecord] = deque(maxlen=ledger_capacity)
        self.dropped_records = 0     # quarantines evicted past capacity
        self.total_quarantined = 0
        self.accepted = 0
        self._seq = 0
        self._ema_rms: Optional[float] = None

    # -- admission --------------------------------------------------------

    def limits(self) -> Tuple[float, float]:
        """Current (rms_limit, abs_limit) for the device-side verdict,
        +inf where a gate is disabled or still warming up. Rounded to
        f32 so the fused fold's comparison and `record`'s host
        classification see the same thresholds."""
        abs_limit = self.max_abs if self.max_abs is not None \
            else float("inf")
        if (self.outlier_factor is not None and self._ema_rms is not None
                and self.accepted >= self.warmup_chunks):
            rms_limit = float(np.float32(self.outlier_factor
                                         * self._ema_rms))
        else:
            rms_limit = float("inf")
        return rms_limit, float(np.float32(abs_limit))

    def admit(self, X_batch, y_batch) -> Tuple[bool, Optional[str]]:
        """Decide one chunk standalone (its own probe dispatch; the
        dense service path fuses the probe into the fold and calls
        `record` with the health directly). Returns (True, None) or
        (False, reason)."""
        health = np.asarray(_batch_health(X_batch, y_batch))
        return self.record(health, tuple(int(s) for s in X_batch.shape))

    def record(self, health, shape) -> Tuple[bool, Optional[str]]:
        """Classify one chunk's `[finite, rms, max_abs]` probe result:
        ledger + counters on reject, EMA reference update on accept."""
        self._seq += 1
        rms_limit, abs_limit = self.limits()
        finite = bool(health[0])
        rms, max_abs = float(health[1]), float(health[2])
        if not finite:
            self._quarantine("nonfinite", shape, max_abs, float("inf"))
            return False, "nonfinite"
        if max_abs > abs_limit:
            self._quarantine("magnitude", shape, max_abs, abs_limit)
            return False, "magnitude"
        if not rms <= rms_limit:     # NaN-safe: an unreadable rms rejects
            self._quarantine("outlier", shape, rms, rms_limit)
            return False, "outlier"
        self.accepted += 1
        if np.isfinite(rms):     # an overflowed (inf) rms must never
            if self._ema_rms is None:   # poison the reference scale
                self._ema_rms = rms
            else:
                d = self.ema_decay
                self._ema_rms = d * self._ema_rms + (1.0 - d) * rms
        return True, None

    def _quarantine(self, reason: str, shape, stat: float,
                    threshold: float) -> None:
        if len(self.ledger) == self.ledger.maxlen:
            self.dropped_records += 1
            obs.inc("stream.quarantine_dropped")
        self.ledger.append(QuarantineRecord(self._seq, reason, shape,
                                            stat, threshold))
        self.total_quarantined += 1
        obs.inc("stream.quarantine", reason=reason)

    # -- introspection ----------------------------------------------------

    @property
    def reference_rms(self) -> Optional[float]:
        """EMA RMS of accepted traffic (None until the first accept)."""
        return self._ema_rms

    def summary(self) -> dict:
        by_reason: dict = {}
        for rec in self.ledger:
            by_reason[rec.reason] = by_reason.get(rec.reason, 0) + 1
        return {"accepted": self.accepted,
                "quarantined": self.total_quarantined,
                "ledger": len(self.ledger),
                "dropped_records": self.dropped_records,
                "by_reason_in_ledger": by_reason,
                "reference_rms": self._ema_rms}
