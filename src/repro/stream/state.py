"""Streaming sufficient-statistics state (checkpointable pytree).

The DSML estimator never touches raw samples after the reduction to
`(Sigma, c)`, and those statistics are *additive over samples*. A
stream of minibatches therefore folds into a fixed-size `StreamState`
— per-task running covariance/correlation means plus an effective
sample count — and the full pipeline (lasso, debias, threshold) can be
re-run at any time from the state alone. Three ingestion regimes:

  * plain (`decay=1`):   exact running means; ingesting a dataset in
                          any chunking reproduces `sufficient_stats`
                          on the concatenation (to float roundoff).
  * exponential decay:    `decay<1` multiplies the *old* effective
                          count per ingested chunk, so a chunk that is
                          j chunks old carries weight decay^j — cheap
                          forgetting for non-stationary traffic.
  * sliding window:       `WindowState` keeps the last w chunk stats
                          in a ring buffer; `window_stats` aggregates
                          exactly the surviving chunks.

All functions are pure and jit-safe; `StreamState` round-trips through
`checkpoint/io.save_pytree` unchanged.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.engine import sufficient_stats


class StreamState(NamedTuple):
    Sigmas: jnp.ndarray      # (m, p, p) running weighted-mean covariance
    cs: jnp.ndarray          # (m, p)    running weighted-mean correlation
    counts: jnp.ndarray      # (m,)      effective sample count (decays)
    beta_local: jnp.ndarray  # (m, p)    last step-1 lasso (refit warm start)
    Ms: jnp.ndarray          # (m, p, p) last debias M (refit warm start)
    beta_u: jnp.ndarray      # (m, p)    last debiased estimates
    beta_tilde: jnp.ndarray  # (m, p)    current servable estimates
    support: jnp.ndarray     # (p,) bool current shared support
    generation: jnp.ndarray  # ()   int32 refit generation


def init_stream_state(m: int, p: int, dtype=jnp.float32) -> StreamState:
    """Empty state for m tasks in p dimensions (zero samples seen)."""
    return StreamState(
        Sigmas=jnp.zeros((m, p, p), dtype),
        cs=jnp.zeros((m, p), dtype),
        counts=jnp.zeros((m,), dtype),
        beta_local=jnp.zeros((m, p), dtype),
        Ms=jnp.zeros((m, p, p), dtype),
        beta_u=jnp.zeros((m, p), dtype),
        beta_tilde=jnp.zeros((m, p), dtype),
        support=jnp.zeros((p,), bool),
        generation=jnp.zeros((), jnp.int32),
    )


@jax.jit
def ingest_stats(state: StreamState, Sigma_b: jnp.ndarray, c_b: jnp.ndarray,
                 count_b: jnp.ndarray, decay=1.0) -> StreamState:
    """Fold one chunk's *mean* statistics into the running means.

    Sigma_b (m, p, p) and c_b (m, p) are chunk means weighted by
    `count_b` (scalar or (m,) effective samples). `decay` scales the
    old effective count first, so with decay d and chunk counts n_k the
    state equals  sum_k d^{K-k} n_k stats_k / sum_k d^{K-k} n_k.
    """
    dt = state.Sigmas.dtype
    count_b = jnp.broadcast_to(jnp.asarray(count_b, dt).reshape(-1),
                               state.counts.shape)
    w_old = jnp.asarray(decay, dt) * state.counts
    total = w_old + count_b
    denom = jnp.maximum(total, jnp.finfo(dt).tiny)
    Sigmas = (w_old[:, None, None] * state.Sigmas
              + count_b[:, None, None] * Sigma_b) / denom[:, None, None]
    cs = (w_old[:, None] * state.cs + count_b[:, None] * c_b) / denom[:, None]
    return state._replace(Sigmas=Sigmas, cs=cs, counts=total)


@jax.jit
def ingest(state: StreamState, X_batch: jnp.ndarray, y_batch: jnp.ndarray,
           weights: jnp.ndarray | None = None, decay=1.0) -> StreamState:
    """Rank-n update from a raw minibatch. X (m, n, p), y (m, n).

    The chunk reduction is `sufficient_stats`, i.e. on TPU the fused
    Pallas `kernels/rank_update` kernel — Sigma_b and c_b from ONE
    pass over the chunk (DESIGN.md §11) — and the XLA einsum oracle on
    CPU.

    `weights` (m, n) importance-weights samples within the chunk (the
    chunk's effective count becomes sum(weights) per task); `decay`
    applies exponential forgetting to everything already ingested.
    """
    n = X_batch.shape[1]
    Sigma_b, c_b = sufficient_stats(X_batch, y_batch, weights)
    if weights is None:
        count_b = jnp.full(state.counts.shape, n, state.counts.dtype)
    else:
        count_b = jnp.sum(weights, axis=1).astype(state.counts.dtype)
        # sufficient_stats normalizes by n, not sum(w): rescale the chunk
        # means so count_b * mean recovers the weighted sums.
        scale = n / jnp.maximum(count_b, jnp.finfo(state.counts.dtype).tiny)
        Sigma_b = Sigma_b * scale[:, None, None]
        c_b = c_b * scale[:, None]
    return ingest_stats(state, Sigma_b, c_b, count_b, decay)


@jax.jit
def merge(a: StreamState, b: StreamState) -> StreamState:
    """Additive merge of two states' statistics (shards of one stream).

    Model fields (beta/support/generation) follow `a`; only the
    sufficient statistics and counts combine.
    """
    return ingest_stats(a, b.Sigmas, b.cs, b.counts)


# ---------------------------------------------------------------------------
# sliding window
# ---------------------------------------------------------------------------

class WindowState(NamedTuple):
    Sigmas: jnp.ndarray   # (w, m, p, p) per-slot chunk mean covariance
    cs: jnp.ndarray       # (w, m, p)    per-slot chunk mean correlation
    counts: jnp.ndarray   # (w, m)       per-slot sample counts (0 = empty)
    head: jnp.ndarray     # ()  int32    next slot to overwrite
    seen: jnp.ndarray     # ()  int32    total chunks ever ingested


def init_window(window: int, m: int, p: int, dtype=jnp.float32) -> WindowState:
    return WindowState(
        Sigmas=jnp.zeros((window, m, p, p), dtype),
        cs=jnp.zeros((window, m, p), dtype),
        counts=jnp.zeros((window, m), dtype),
        head=jnp.zeros((), jnp.int32),
        seen=jnp.zeros((), jnp.int32),
    )


@jax.jit
def window_ingest(win: WindowState, X_batch: jnp.ndarray,
                  y_batch: jnp.ndarray) -> WindowState:
    """Write one chunk's stats into the ring buffer (evicts the oldest)."""
    n = X_batch.shape[1]
    Sigma_b, c_b = sufficient_stats(X_batch, y_batch)
    w = win.counts.shape[0]
    h = win.head
    return WindowState(
        Sigmas=win.Sigmas.at[h].set(Sigma_b.astype(win.Sigmas.dtype)),
        cs=win.cs.at[h].set(c_b.astype(win.cs.dtype)),
        counts=win.counts.at[h].set(
            jnp.full(win.counts.shape[1:], n, win.counts.dtype)),
        head=(h + 1) % w,
        seen=win.seen + 1,
    )


@jax.jit
def window_stats(win: WindowState
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Aggregate the surviving chunks: (Sigmas (m,p,p), cs (m,p), counts (m,)).

    Equals `sufficient_stats` on the concatenation of the last
    min(seen, window) chunks.
    """
    total = jnp.sum(win.counts, axis=0)                       # (m,)
    denom = jnp.maximum(total, jnp.finfo(win.counts.dtype).tiny)
    Sigmas = jnp.einsum("wm,wmij->mij", win.counts, win.Sigmas) \
        / denom[:, None, None]
    cs = jnp.einsum("wm,wmi->mi", win.counts, win.cs) / denom[:, None]
    return Sigmas, cs, total
