"""Post-refit invariant checks: is a refreshed model fit to serve?

A refit that diverged — a warm start gone stale after a regime shift,
an escalated lambda interacting badly with a short iteration budget —
must never silently replace a good `beta_tilde`. `refit_health` runs
three invariants on a *candidate* state before the service adopts it
(DESIGN.md §15 documents the rollback state machine around it):

* **finiteness** — `beta_local`, `Ms`, `beta_u`, `beta_tilde` all
  finite (a single NaN anywhere condemns the candidate);
* **support sanity** — `|S_hat| <= max_support` when a ceiling is
  configured (a full support on a sparse workload is a classic
  divergence signature);
* **KKT residual** — the engine's own prox-gradient fixed-point
  residual (the quantity `tol=`/`return_iters` early exit checks,
  here evaluated once post-hoc in the eq.-2 convention `refit` solves
  under) must sit under a ceiling. A NaN residual fails the check
  (the comparison is `not (kkt <= ceiling)`), so divergence cannot
  hide behind NaN-poisoned comparisons.

All checks run eagerly on the host (one jitted reduction, three
scalars pulled) — this module is never jit-reachable.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import power_iteration_batched
from repro.kernels.ista_step.ref import ista_step_batched_ref
from repro.stream.state import StreamState


class RefitHealth(NamedTuple):
    healthy: bool
    reason: Optional[str]    # None | "nonfinite_model" | "support_size"
                             # | "kkt_residual"
    kkt_residual: float      # prox-gradient residual of beta_local
    support_size: int        # |S_hat| of the candidate


@jax.jit
def _model_health(Sigmas, cs, beta_local, Ms, beta_u, beta_tilde, lam):
    """[all_finite, kkt_residual] as a (2,) f32.

    The residual replicates `solve_lasso_eq2`'s convention: step sizes
    2/max(2*lam_max, eps) and threshold weight lam/2, so a candidate
    that satisfies the eq.-2 optimality condition has residual ~0
    regardless of how many iterations the refit actually ran.
    """
    finite = (jnp.isfinite(beta_local).all() & jnp.isfinite(Ms).all()
              & jnp.isfinite(beta_u).all() & jnp.isfinite(beta_tilde).all())
    lam_max = power_iteration_batched(Sigmas)
    etas = 2.0 / jnp.maximum(2.0 * lam_max, 1e-12)
    B = beta_local[..., None]
    B_fp = ista_step_batched_ref(Sigmas, jnp.nan_to_num(B), cs[..., None],
                                 etas, 0.5 * jnp.asarray(lam))
    kkt = jnp.max(jnp.abs(B_fp - jnp.nan_to_num(B)))
    return jnp.stack([finite.astype(jnp.float32), kkt.astype(jnp.float32)])


def refit_health(candidate: StreamState, lam, *,
                 kkt_ceiling: float = 1.0,
                 max_support: Optional[int] = None) -> RefitHealth:
    """Judge a candidate refit against the serve-fitness invariants.

    `kkt_ceiling` bounds the eq.-2 prox-gradient residual of the
    candidate's `beta_local` on the candidate's own statistics: a
    converged-ish refit on standardized traffic sits orders of
    magnitude below 1.0, while a diverged one is non-finite or huge.
    `max_support=None` disables the support ceiling.
    """
    stats = np.asarray(_model_health(
        candidate.Sigmas, candidate.cs, candidate.beta_local, candidate.Ms,
        candidate.beta_u, candidate.beta_tilde, lam))
    finite, kkt = bool(stats[0]), float(stats[1])
    support_size = int(np.asarray(jnp.sum(candidate.support)))
    if not finite:
        return RefitHealth(False, "nonfinite_model", kkt, support_size)
    if max_support is not None and support_size > max_support:
        return RefitHealth(False, "support_size", kkt, support_size)
    if not (kkt <= kkt_ceiling):     # NaN residual must fail, not pass
        return RefitHealth(False, "kkt_residual", kkt, support_size)
    return RefitHealth(True, None, kkt, support_size)
