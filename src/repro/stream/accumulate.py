"""Sharded streaming accumulation: engine-level SPMD over a data × task mesh.

Minibatches arrive sharded over the `data` mesh axis (each device owns a
slice of the rows) with tasks sharded over `task`. Every device reduces
its rows to partial unnormalized `(Sigma, c)` sums — a local einsum —
and one `psum_stats` over `data` turns them into the full-chunk
statistics, task-sharded and replicated along `data`. That is the whole
communication story: O(m_local * p^2) per device per chunk, no raw
sample ever crosses a device boundary, and the reduction is the same
additivity that makes `StreamState.ingest` exact.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.stream.state import StreamState, ingest_stats
from repro.substrate import psum_stats, shard_map


def accumulate_stats_fn(mesh: Mesh, data_axis: str = "data",
                        task_axis: str = "task"):
    """The shard-mapped accumulator as a callable (X, y) -> (S, c).

    X (m, n, p) sharded (task, data, -); returns UNNORMALIZED sums
    S = X'X (m, p, p), c = X'y (m, p) over the whole chunk, sharded
    over `task_axis` and replicated along `data_axis` (divide by the
    chunk's n for the mean convention). Exposed separately so probes
    can lower the actual implementation and count its collectives.
    """

    def worker(X_blk, y_blk):
        # X_blk: (m_local, n_local, p) — this device's rows of its tasks.
        S_part = jnp.einsum("tni,tnj->tij", X_blk, X_blk)
        c_part = jnp.einsum("tni,tn->ti", X_blk, y_blk)
        S = psum_stats(S_part, data_axis)
        c = psum_stats(c_part, data_axis)
        return S, c

    return shard_map(
        worker,
        mesh=mesh,
        in_specs=(P(task_axis, data_axis, None), P(task_axis, data_axis)),
        out_specs=(P(task_axis, None, None), P(task_axis, None)),
    )


@lru_cache(maxsize=8)
def _jitted_accumulator(mesh: Mesh, data_axis: str, task_axis: str):
    """One compiled accumulator per (mesh, axes) — ingest is the always-
    on hot path, so per-chunk re-jitting would swamp the psum."""
    return jax.jit(accumulate_stats_fn(mesh, data_axis, task_axis))


def accumulate_stats_sharded(X_batch: jnp.ndarray, y_batch: jnp.ndarray,
                             mesh: Mesh, data_axis: str = "data",
                             task_axis: str = "task"
                             ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Chunk-mean sufficient statistics of a device-sharded minibatch.

    Numerically equal (to roundoff) to `engine.sufficient_stats` on the
    gathered chunk; communicates two psums of partial sums instead.
    """
    n = X_batch.shape[1]
    fn = _jitted_accumulator(mesh, data_axis, task_axis)
    S_sum, c_sum = fn(X_batch, y_batch)
    return S_sum / n, c_sum / n


def ingest_sharded(state: StreamState, X_batch: jnp.ndarray,
                   y_batch: jnp.ndarray, mesh: Mesh, decay=1.0,
                   data_axis: str = "data",
                   task_axis: str = "task") -> StreamState:
    """`stream.state.ingest` with the row reduction run SPMD over `mesh`.

    The state merge itself is elementwise over tasks, so it composes
    with whatever task sharding the caller keeps the state in.
    """
    n = X_batch.shape[1]
    Sigma_b, c_b = accumulate_stats_sharded(X_batch, y_batch, mesh,
                                            data_axis, task_axis)
    return ingest_stats(state, Sigma_b, c_b, n, decay)
