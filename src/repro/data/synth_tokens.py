"""Synthetic token pipeline: a learnable bigram-ish language so the loss
actually falls (pure-noise tokens would bottom out at log V immediately).

Sequences follow a random sparse Markov chain over the vocab; the chain
is fixed per seed, so a model can learn it. Batches stream forever.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models import Batch


def _markov_params(key, vocab: int, branching: int = 4):
    k1, k2 = jax.random.split(key)
    nxt = jax.random.randint(k1, (vocab, branching), 0, vocab)
    logits = jax.random.normal(k2, (vocab, branching))
    return nxt, logits


def synthetic_lm_batches(key, *, vocab: int, batch: int, seq: int,
                         frontend_shape: Optional[tuple] = None
                         ) -> Iterator[Batch]:
    """Yields Batch(tokens, labels[, frontend]) forever."""
    nxt, logits = _markov_params(key, vocab)

    @jax.jit
    def make(key):
        k0, k1, k2 = jax.random.split(key, 3)
        first = jax.random.randint(k0, (batch,), 0, vocab)

        def step(tok, k):
            choice = jax.random.categorical(k, logits[tok])
            return nxt[tok, choice], tok

        ks = jax.random.split(k1, seq)
        _, toks = jax.lax.scan(step, first, ks)
        tokens = toks.T                                    # (batch, seq)
        labels = jnp.concatenate([tokens[:, 1:],
                                  tokens[:, :1] * 0 - 1], axis=1)
        fe = None
        if frontend_shape is not None:
            fe = 0.1 * jax.random.normal(k2, (batch, *frontend_shape))
        return tokens, labels, fe

    i = 0
    while True:
        tokens, labels, fe = make(jax.random.fold_in(key, i))
        yield Batch(tokens=tokens, labels=labels, frontend=fe)
        i += 1
