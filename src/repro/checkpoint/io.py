"""Pytree checkpointing: npz payload + msgpack-free structure encoding.

Leaves are saved flat by tree path; restore maps them back onto a
template pytree (shape/dtype checked). Works for TrainState, params and
serving caches alike.
"""
from __future__ import annotations

import os

import jax
import numpy as np


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = np.asarray(leaf, dtype=np.float32)   # npz-safe upcast
        out[key] = arr
    return out


def save_pytree(path: str, tree) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path if path.endswith(".npz") else path + ".npz",
             **_flatten_with_names(tree))


def restore_pytree(path: str, template):
    """Restore into the structure of `template` (shape/dtype validated)."""
    fname = path if path.endswith(".npz") else path + ".npz"
    data = np.load(fname)
    named = _flatten_with_names(template)
    missing = [k for k in named if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint missing {len(missing)} leaves, "
                       f"e.g. {missing[:3]}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    new_leaves = []
    for (pth, leaf), _ in zip(flat, leaves):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in pth)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
