"""Pytree checkpointing: npz payload + msgpack-free structure encoding.

Leaves are saved flat by tree path; restore maps them back onto a
template pytree (shape/dtype checked). Works for TrainState, params and
serving caches alike.

Crash safety (DESIGN.md §15): `save_pytree` never writes the target
file in place. The payload lands in a same-directory temp file that is
flushed, fsynced, and `os.replace`d over the destination, so a process
killed mid-save leaves either the previous complete checkpoint or a
stray `*.tmp.*` file — never a torn npz that bricks restart. Torn or
otherwise unreadable files surface as `CheckpointError` (a `ValueError`
subclass) with the path named, as do template mismatches — the cryptic
`BadZipFile` / npz key errors that used to escape are wrapped.
"""
from __future__ import annotations

import os

import jax
import numpy as np


class CheckpointError(ValueError):
    """A checkpoint file is unreadable or does not match its template."""


def _flatten_with_names(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
            arr = np.asarray(leaf, dtype=np.float32)   # npz-safe upcast
        out[key] = arr
    return out


def npz_safe_dtype(dtype) -> np.dtype:
    """The on-disk dtype a leaf of `dtype` lands as — mirrors the
    bf16 -> f32 upcast `save_pytree` applies (restore casts back), so
    compatibility validators compare against what is actually saved."""
    arr = np.asarray(jax.numpy.zeros((), dtype))
    if arr.dtype.kind == "V" or str(arr.dtype) == "bfloat16":
        return np.dtype(np.float32)
    return arr.dtype


def atomic_write(path: str, write_fn) -> None:
    """Write `path` atomically: `write_fn(file_obj)` fills a
    same-directory temp file, which is flushed + fsynced and renamed
    over the destination. On failure the temp file is removed and the
    previous `path` contents (if any) are untouched."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.remove(tmp)
        raise


def save_pytree(path: str, tree) -> None:
    fname = path if path.endswith(".npz") else path + ".npz"
    flat = _flatten_with_names(tree)
    atomic_write(fname, lambda f: np.savez(f, **flat))


def load_npz(path: str):
    """`np.load` with torn/corrupt files surfaced as CheckpointError."""
    fname = path if path.endswith(".npz") else path + ".npz"
    try:
        return np.load(fname)
    except FileNotFoundError:
        raise
    except Exception as e:
        raise CheckpointError(
            f"checkpoint '{fname}' is unreadable (torn write or "
            f"corruption): {type(e).__name__}: {e}") from e


def restore_pytree(path: str, template):
    """Restore into the structure of `template` (shape/dtype validated).

    Raises `CheckpointError` naming the file and the offending leaves
    when the checkpoint is torn, was saved from a different structure
    (missing leaves), or carries mismatched shapes. Extra keys on disk
    are legal (a template may restore a subset), but are reported
    alongside missing-leaf errors since together they usually mean
    "wrong checkpoint for this template".
    """
    fname = path if path.endswith(".npz") else path + ".npz"
    data = load_npz(fname)
    named = _flatten_with_names(template)
    missing = [k for k in named if k not in data.files]
    if missing:
        extra = [k for k in data.files if k not in named]
        hint = f"; file has {len(extra)} unexpected keys e.g. {extra[:3]}" \
            if extra else ""
        raise CheckpointError(
            f"checkpoint '{fname}' does not match the restore template: "
            f"{len(missing)} leaves missing, e.g. {missing[:3]}{hint}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    flat = jax.tree_util.tree_flatten_with_path(template)[0]
    new_leaves = []
    for (pth, leaf), _ in zip(flat, leaves):
        key = "/".join(
            str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k))))
            for k in pth)
        arr = data[key]
        if arr.shape != leaf.shape:
            raise CheckpointError(
                f"checkpoint '{fname}' leaf '{key}': shape {arr.shape} "
                f"!= template {leaf.shape}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)
