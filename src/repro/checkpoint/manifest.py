"""Crash-safe generation checkpoints: atomic writes + checksummed manifest.

`CheckpointStore` is the persistence layer a long-running service can
die on at any instruction and still restart from (DESIGN.md §15):

* every payload write is atomic (`io.atomic_write`: same-directory temp
  file + fsync + rename), so a SIGKILL mid-save leaves the previous
  complete generation, never a torn npz;
* `MANIFEST.json` — itself written atomically — records each retained
  generation with its file name, byte size, and sha256, newest first;
* the last `keep` generations are retained, older payloads pruned;
* `load()` walks the manifest newest-first and falls back past any
  entry whose file is missing, fails its checksum, or no longer
  restores against the template — each skip is recorded to
  `repro.obs` (`checkpoint.fallback{reason}`) so silent corruption is
  still observable. A manifest that is itself unreadable degrades to a
  directory scan over `ckpt_*.npz` (checksums unavailable, restore
  errors still caught).

The store is deliberately dumb about contents: it persists any pytree
`io.save_pytree` can, tagged with a caller-supplied integer generation.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
from typing import List, Optional, Tuple

from repro import obs
from repro.checkpoint.io import (
    CheckpointError, atomic_write, restore_pytree, save_pytree,
)

MANIFEST_NAME = "MANIFEST.json"
_CKPT_RE = re.compile(r"^ckpt_(\d{8})\.npz$")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class CheckpointStore:
    """Retained-generation checkpoint directory with a checksummed
    manifest. `save` is crash-safe; `load` survives a corrupted head by
    falling back through older retained generations."""

    def __init__(self, dirpath: str, keep: int = 3):
        if keep < 1:
            raise ValueError(f"keep must be >= 1, got {keep}")
        self.dirpath = dirpath
        self.keep = keep

    # -- paths ------------------------------------------------------------

    def _manifest_path(self) -> str:
        return os.path.join(self.dirpath, MANIFEST_NAME)

    def _ckpt_name(self, generation: int) -> str:
        return f"ckpt_{generation:08d}.npz"

    # -- manifest ---------------------------------------------------------

    def _read_manifest(self) -> Optional[List[dict]]:
        """Manifest entries (newest first), or None when the manifest is
        missing/unreadable and the caller should fall back to a scan."""
        try:
            with open(self._manifest_path()) as f:
                doc = json.load(f)
            entries = doc["checkpoints"]
            if not isinstance(entries, list):
                raise CheckpointError("manifest 'checkpoints' not a list")
            return entries
        except FileNotFoundError:
            return None
        except Exception as e:
            obs.inc("checkpoint.fallback", reason="manifest_unreadable")
            obs.inc("checkpoint.manifest_error",
                    kind=type(e).__name__)
            return None

    def _write_manifest(self, entries: List[dict]) -> None:
        doc = {"version": 1, "checkpoints": entries}
        payload = json.dumps(doc, indent=2).encode() + b"\n"
        atomic_write(self._manifest_path(), lambda f: f.write(payload))

    # -- save -------------------------------------------------------------

    def save(self, tree, generation: int) -> str:
        """Persist `tree` as `generation`, update the manifest, prune
        generations past `keep`. Returns the payload path."""
        generation = int(generation)
        name = self._ckpt_name(generation)
        path = os.path.join(self.dirpath, name)
        save_pytree(path, tree)
        entry = {"generation": generation, "file": name,
                 "nbytes": os.path.getsize(path), "sha256": _sha256(path)}
        entries = [e for e in (self._read_manifest() or [])
                   if e.get("file") != name]
        entries.append(entry)
        entries.sort(key=lambda e: e.get("generation", -1), reverse=True)
        retained, pruned = entries[:self.keep], entries[self.keep:]
        self._write_manifest(retained)
        for old in pruned:
            stale = os.path.join(self.dirpath, str(old.get("file")))
            try:
                os.remove(stale)
            except OSError:
                obs.inc("checkpoint.prune_error")
        obs.inc("checkpoint.saved")
        obs.set_gauge("checkpoint.head_generation", generation)
        return path

    # -- load -------------------------------------------------------------

    def _candidates(self) -> List[Tuple[int, str, Optional[str]]]:
        """(generation, filename, sha256-or-None), newest first — from
        the manifest when readable, else a directory scan."""
        entries = self._read_manifest()
        if entries is not None:
            out = []
            for e in entries:
                try:
                    out.append((int(e["generation"]), str(e["file"]),
                                e.get("sha256")))
                except (KeyError, TypeError, ValueError):
                    obs.inc("checkpoint.fallback", reason="manifest_entry")
            return sorted(out, reverse=True)
        try:
            names = os.listdir(self.dirpath)
        except OSError:
            return []
        found = []
        for n in names:
            m = _CKPT_RE.match(n)
            if m:
                found.append((int(m.group(1)), n, None))
        return sorted(found, reverse=True)

    def generations(self) -> List[int]:
        """Retained generations, newest first."""
        return [g for g, _, _ in self._candidates()]

    def load(self, template):
        """Restore the newest loadable generation into `template`.

        Returns `(tree, generation)`. A corrupted head — missing file,
        checksum mismatch, torn npz, template mismatch — is skipped
        (recorded as `checkpoint.fallback{reason}`) and the next
        retained generation is tried; `CheckpointError` is raised only
        when no retained generation restores.
        """
        candidates = self._candidates()
        tried = []
        for generation, name, sha in candidates:
            path = os.path.join(self.dirpath, name)
            if not os.path.exists(path):
                obs.inc("checkpoint.fallback", reason="missing_file")
                tried.append(f"{name}: missing")
                continue
            if sha is not None and _sha256(path) != sha:
                obs.inc("checkpoint.fallback", reason="checksum")
                tried.append(f"{name}: checksum mismatch")
                continue
            try:
                tree = restore_pytree(path, template)
            except Exception as e:
                obs.inc("checkpoint.fallback", reason="restore_error")
                tried.append(f"{name}: {type(e).__name__}: {e}")
                continue
            obs.inc("checkpoint.loaded")
            obs.set_gauge("checkpoint.loaded_generation", generation)
            return tree, generation
        detail = "; ".join(tried) if tried else "no checkpoints found"
        raise CheckpointError(
            f"no loadable checkpoint in '{self.dirpath}' "
            f"({len(candidates)} candidates): {detail}")
