import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init). Everything below is ordinary code.

import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ASSIGNED  # noqa: E402
from repro.launch.hlo import analyze_hlo, roofline  # noqa: E402
from repro.launch.mesh import HW, make_production_mesh  # noqa: E402
from repro.launch.specs import SHAPES, input_specs  # noqa: E402
from repro.substrate import make_mesh  # noqa: E402
from repro.models import stack_plan  # noqa: E402
from repro.serving.engine import make_prefill_step, make_serve_step  # noqa: E402
from repro.sharding.rules import (  # noqa: E402
    batch_pspecs, cache_pspecs, logits_pspec, named, opt_pspecs,
    param_pspecs, train_state_pspecs,
)
from repro.training.step import make_train_step  # noqa: E402


def lower_combo(arch: str, shape: str, *, multi_pod: bool,
                mesh_override: tuple | None = None):
    """Lower + compile one (arch x shape x mesh) combo; returns a record.

    mesh_override: (data, model) single-pod shape for §Perf experiments
    (e.g. (32, 8) gives minitron's 24 heads a dividing TP degree)."""
    spec = input_specs(arch, shape)
    mesh_name = ("x".join(map(str, mesh_override)) if mesh_override
                 else ("2x16x16" if multi_pod else "16x16"))
    if spec is None:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skipped",
                "note": "long_500k out of regime for enc-dec (DESIGN.md §7)"}
    cfg, mode = spec.cfg, spec.mode
    info = SHAPES[shape]
    if mesh_override:
        mesh = make_mesh(tuple(mesh_override), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    B, S = info["batch"], info["seq"]
    micro = 0

    if mode == "train":
        state, batch = spec.args
        lp = NamedSharding(mesh, logits_pspec(mesh, cfg.padded_vocab,
                                              batch.tokens.shape[1]))
        # auto gradient-accumulation: keep the remat-saved residual stream
        # (L x B_local x S x d, bf16) under ~4 GB/chip
        dp = (mesh.shape.get("pod", 1)) * mesh.shape["data"]
        resid = (cfg.n_layers * (B // dp) * batch.tokens.shape[1]
                 * cfg.d_model * 2)
        micro = 1
        while micro < 16 and resid / micro > 4e9 and (B // dp) % (2 * micro) == 0:
            micro *= 2
        micro = max(micro, 4) if (B // dp) % 4 == 0 else micro
        fn = make_train_step(
            cfg, logits_pspec=lp, microbatches=micro,
            grads_pspec=named(mesh, opt_pspecs(state.params, mesh)))
        in_sh = (named(mesh, train_state_pspecs(state, mesh)),
                 named(mesh, batch_pspecs(mesh, B, cfg.frontend is not None)))
        jitted = jax.jit(fn, in_shardings=in_sh, donate_argnums=(0,))
        args = (state, batch)
        if cfg.moe is not None and os.environ.get("MOE_SHARDING", "1") == "1":
            from repro.models.moe import moe_sharding
            from repro.sharding.rules import dp_axes
            eb = (NamedSharding(mesh, P("model", None, None))
                  if os.environ.get("MOE_EXPERT_BATCH", "1") == "1" else None)
            ctx = moe_sharding(
                expert_batch=eb,
                tokens=NamedSharding(mesh, P(dp_axes(mesh), None)))
        else:
            import contextlib
            ctx = contextlib.nullcontext()
        import contextlib as _cl
        globals()["_moe_ctx"] = ctx
    elif mode == "prefill":
        params, batch = spec.args
        fn = make_prefill_step(cfg, cache_len=S)
        bspec = batch_pspecs(mesh, B, cfg.frontend is not None)
        in_sh = (named(mesh, param_pspecs(params, mesh)),
                 named(mesh, Batch_like(bspec, batch)))
        jitted = jax.jit(fn, in_shardings=in_sh)
        args = (params, batch)
    else:  # decode
        params, token, pos, caches = spec.args
        fn = make_serve_step(cfg)
        tok_spec = batch_pspecs(mesh, B).tokens
        in_sh = (named(mesh, param_pspecs(params, mesh)),
                 NamedSharding(mesh, tok_spec),
                 NamedSharding(mesh, P()),
                 named(mesh, cache_pspecs(mesh, caches, B)))
        jitted = jax.jit(fn, in_shardings=in_sh)
        args = (params, token, pos, caches)

    import contextlib
    ctx = globals().get("_moe_ctx") or contextlib.nullcontext()
    t0 = time.time()
    with ctx:
        lowered = jitted.lower(*args)
    globals()["_moe_ctx"] = None
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):   # jax<=0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_acc = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes"):
            mem[f] = getattr(ma, f, 0)

    pat, n_groups, tail = stack_plan(cfg)
    hlo = compiled.as_text()
    ana = analyze_hlo(hlo, default_trip=n_groups)
    coll = ana["collectives"]
    # analytic (trip-aware) flops; cost_analysis counts loop bodies once.
    flops_analytic = ana["flops"]
    # memory: XLA's bytes-accessed is fusion-aware but loop-once; scale it
    # by the loop multiplier inferred from the flops ratio (the estimator
    # used for every recorded artifact — keeps before/after comparable).
    # The traffic-weighted alternative and the raw per-instruction operand
    # sum are recorded alongside as upper bounds.
    loop_mult = max(1.0, flops_analytic / max(flops, 1.0))
    bytes_scaled = bytes_acc * loop_mult
    bytes_traffic_weighted = bytes_acc * max(1.0, ana.get("traffic_eff_mult", 1.0))
    terms = roofline(flops_analytic, bytes_scaled, coll.get("total", 0.0))

    # MODEL_FLOPS (useful-compute reference)
    n_active = cfg.active_param_count()
    tokens = {"train": B * S, "prefill": B * S, "decode": B}[mode]
    factor = 6 if mode == "train" else 2
    chips = 512 if multi_pod else 256
    model_flops = factor * n_active * tokens
    ratio = model_flops / max(flops_analytic * chips, 1.0)

    rec = {
        "arch": arch, "shape": shape,
        "mesh": mesh_name,
        "status": "ok", "mode": mode, "note": spec.note,
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "flops_per_chip": flops_analytic, "bytes_per_chip": bytes_scaled,
        "bytes_upper_bound": ana["bytes"],
        "bytes_traffic_weighted": bytes_traffic_weighted,
        "flops_per_chip_xla": flops, "bytes_per_chip_xla": bytes_acc,
        "collective_bytes_per_chip": coll, "memory": mem,
        "roofline": terms,
        "model_flops": model_flops, "useful_ratio": ratio,
        "n_params": cfg.param_count(), "n_active": n_active,
        "microbatches": micro, "hlo_bytes": len(hlo),
    }
    return rec


def Batch_like(bspec, batch):
    """Match the Batch pspec tree to a Batch that may have None members."""
    from repro.models import Batch
    return Batch(tokens=bspec.tokens,
                 labels=bspec.labels if batch.labels is not None else None,
                 frontend=bspec.frontend if batch.frontend is not None else None)


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="DxM single-pod override, e.g. 32x8 (perf exps)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()
    mesh_override = (tuple(int(x) for x in args.mesh.split("x"))
                     if args.mesh else None)

    archs = ASSIGNED if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = args.mesh if mesh_override else (
                    "2x16x16" if mp else "16x16")
                tag = f"{arch}__{shape}__{mesh_name}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[skip existing] {tag}")
                    continue
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = lower_combo(arch, shape, multi_pod=mp,
                                      mesh_override=mesh_override)
                except Exception as e:  # record failures as bugs to fix
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
                status = rec["status"]
                rf = rec.get("roofline", {})
                print(f"  -> {status} compile={rec.get('t_compile_s', '-')}s "
                      f"bottleneck={rf.get('bottleneck', '-')}", flush=True)


if __name__ == "__main__":
    main()
