"""Abstract input specs (ShapeDtypeStructs) for every (arch x shape) combo.

No device memory is allocated: parameter/optimizer/cache shapes come from
`jax.eval_shape` over the real initializers, so the dry-run lowers the
exact production byte-for-byte shapes.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import Batch, init_caches
from repro.models.config import ModelConfig
from repro.training.step import init_train_state

SHAPES = {
    "train_4k":    dict(seq=4096,   batch=256, mode="train"),
    "prefill_32k": dict(seq=32768,  batch=32,  mode="prefill"),
    "decode_32k":  dict(seq=32768,  batch=128, mode="decode"),
    "long_500k":   dict(seq=524288, batch=1,   mode="decode"),
}

# archs that natively handle 500k decode (bounded state / local window)
_NATIVE_LONG = {"mamba2-1.3b", "recurrentgemma-9b"}
# enc-dec: a 500k-token decoder cache is out of the model's regime (skip,
# noted in DESIGN.md §7)
_SKIP_LONG = {"seamless-m4t-medium"}
_SWA_WINDOW = 4096


class ComboSpec(NamedTuple):
    cfg: ModelConfig
    mode: str                       # train | prefill | decode
    args: tuple                     # ShapeDtypeStruct pytrees
    note: str


def arch_for_shape(arch: str, shape: str) -> Optional[tuple]:
    """Returns (cfg, note) with any long-context variant applied, or None
    if the combo is skipped (recorded in DESIGN.md)."""
    cfg = get_config(arch)
    note = ""
    if shape == "long_500k":
        if arch in _SKIP_LONG:
            return None
        if arch not in _NATIVE_LONG:
            cfg = cfg.replace(window=_SWA_WINDOW)
            note = f"sliding-window variant (window={_SWA_WINDOW})"
    return cfg, note


def _frontend_spec(cfg: ModelConfig, batch: int):
    if cfg.frontend is None:
        return None
    return jax.ShapeDtypeStruct((batch, cfg.n_frontend_tokens, cfg.d_model),
                                jnp.float32)


def _token_len(cfg: ModelConfig, seq: int) -> int:
    """Text-token length so that total decoder context == seq."""
    if cfg.arch_type == "vlm":
        return seq - cfg.n_frontend_tokens
    return seq


def input_specs(arch: str, shape: str) -> Optional[ComboSpec]:
    resolved = arch_for_shape(arch, shape)
    if resolved is None:
        return None
    cfg, note = resolved
    info = SHAPES[shape]
    seq, batch, mode = info["seq"], info["batch"], info["mode"]
    key = jax.random.PRNGKey(0)

    if mode == "train":
        S = _token_len(cfg, seq)
        state = jax.eval_shape(lambda: init_train_state(key, cfg))
        tok = jax.ShapeDtypeStruct((batch, S), jnp.int32)
        batch_spec = Batch(tokens=tok, labels=tok,
                           frontend=_frontend_spec(cfg, batch))
        return ComboSpec(cfg, mode, (state, batch_spec), note)

    if mode == "prefill":
        S = _token_len(cfg, seq)
        params = jax.eval_shape(lambda: init_train_state(key, cfg)).params
        tok = jax.ShapeDtypeStruct((batch, S), jnp.int32)
        batch_spec = Batch(tokens=tok, labels=None,
                           frontend=_frontend_spec(cfg, batch))
        return ComboSpec(cfg, mode, (params, batch_spec), note)

    # decode: ONE token against a cache of `seq`
    params = jax.eval_shape(lambda: init_train_state(key, cfg)).params
    caches = jax.eval_shape(lambda: init_caches(cfg, batch, seq))
    token = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ComboSpec(cfg, mode, (params, token, pos, caches), note)
