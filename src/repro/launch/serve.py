"""Production serving launcher: batched greedy decoding for any --arch.

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-9b \
        --reduced --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ASSIGNED, get_config, smoke
from repro.models import init_params
from repro.serving.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ASSIGNED)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke(cfg)
    params = init_params(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                     (args.batch, cfg.n_frontend_tokens,
                                      cfg.d_model))
    t0 = time.time()
    out = jax.block_until_ready(
        greedy_generate(params, cfg, prompt, steps=args.new_tokens,
                        frontend=fe))
    print(f"{cfg.name}: generated {args.batch}x{args.new_tokens} tokens "
          f"in {time.time()-t0:.1f}s (incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
