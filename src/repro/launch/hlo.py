"""Post-SPMD HLO analysis: collective-byte accounting with while-loop
trip-count awareness (scan bodies execute `trip` times but appear once in
the module text), plus the three-term roofline derivation.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

from repro.launch.mesh import HW

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
# all-reduce moves ~2x the buffer (reduce-scatter + all-gather phases)
_MULT = {"all-reduce": 2.0}

_SHAPE_RE = re.compile(r"\b(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_WHILE_RE = re.compile(r"while\(")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALL_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_bytes(text: str) -> int:
    """Sum bytes of every dtype[shape] group in an instruction's output."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_computations(hlo: str):
    """Split module text into {computation-name: lines}, plus the entry name."""
    comps: Dict[str, list] = {}
    current = None
    entry = None
    for line in hlo.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            current = m.group(2)
            if m.group(1):
                entry = current
            comps[current] = []
            continue
        if line.strip() == "}":
            current = None
            continue
        if current is not None:
            comps[current].append(line)
    return comps, entry


def collective_bytes(hlo: str, default_trip: int = 1) -> dict:
    """Per-device collective bytes, scaled by while-loop trip counts.

    Trip counts are recovered from the loop-condition computation's
    comparison constant; when that fails, `default_trip` is used for
    while bodies (pass the model's scan length).
    """
    comps, entry = parse_computations(hlo)

    # computation -> (body, cond) pairs of while instructions inside it
    while_edges = defaultdict(list)
    call_edges = defaultdict(list)
    for cname, lines in comps.items():
        for ln in lines:
            if _WHILE_RE.search(ln):
                body = _BODY_RE.search(ln)
                cond = re.search(r"condition=%?([\w\.\-]+)", ln)
                if body:
                    while_edges[cname].append(
                        (body.group(1), cond.group(1) if cond else None))
            else:
                for callee in _CALL_RE.findall(ln):
                    call_edges[cname].append(callee)

    def trip_count(cond_name) -> int:
        if cond_name and cond_name in comps:
            consts = [int(c) for ln in comps[cond_name]
                      for c in _CONST_RE.findall(ln)]
            big = [c for c in consts if c > 1]
            if big:
                return max(big)
        return default_trip

    # propagate multipliers from the entry computation
    if entry is None:
        for cname in comps:
            if "main" in cname:
                entry = cname
                break
    if entry is None and comps:
        entry = next(iter(comps))
    if entry is None:
        return {"total": 0.0}
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    stack = [entry]
    seen = set()
    while stack:
        c = stack.pop()
        if c in seen:
            continue
        seen.add(c)
        for body, cond in while_edges.get(c, ()):
            mult[body] = max(mult[body], mult[c] * trip_count(cond))
            stack.append(body)
        for callee in call_edges.get(c, ()):
            if callee in comps:
                mult[callee] = max(mult[callee], mult[c])
                stack.append(callee)

    per_kind = defaultdict(float)
    for cname, lines in comps.items():
        m = mult.get(cname, 1.0)
        for ln in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", ln):
                    lhs = ln.split(" = ")[0] + " = " + \
                        ln.split(" = ")[1].split(kind)[0] if " = " in ln else ln
                    per_kind[kind] += _shape_bytes(lhs) * m * _MULT.get(kind, 1.0)
                    break
    per_kind["total"] = sum(v for k, v in per_kind.items() if k != "total")
    return dict(per_kind)


# The lhs operand of a dot may appear bare (`dot(%a, %b)`) or typed
# (`dot(f32[64,64]{1,0} %a, ...)`) depending on the XLA text vintage;
# dots also sit inside fusion computations called from a scan's while
# body, whose FLOPs must scale by the trip count (the computation
# multiplier below follows `calls=` edges, so each fusion inherits its
# caller's while multiplier).
_DOT_RE = re.compile(
    r"%?([\w\.\-]+) = (\w+)\[([\d,]*)\][^=]*"
    r"dot\((?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%?([\w\.\-]+)")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+) = (\w+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")


def _dims(text: str):
    return [int(d) for d in text.split(",") if d]


def analyze_hlo(hlo: str, default_trip: int = 1) -> dict:
    """Trip-count-aware analytic accounting over the post-SPMD module:

      flops — 2*M*N*K of every dot, scaled by the executing computation's
              while-loop multiplier (XLA's cost_analysis counts loop
              bodies ONCE, badly undercounting scanned stacks);
      bytes — operand reads + output writes of top-level instructions
              (entry + loop bodies), i.e. fusion-boundary HBM traffic;
      collectives — per-kind bytes (all-reduce counted 2x).

    Returns {"flops", "bytes", "collectives": {...}}.
    """
    comps, entry = parse_computations(hlo)
    mults = _computation_multipliers(comps, entry, default_trip)

    # name -> (dtype, dims) map for every instruction definition
    shapes = {}
    for lines in comps.values():
        for ln in lines:
            m = _DEF_RE.match(ln)
            if m:
                shapes[m.group(1)] = (m.group(2), _dims(m.group(3)))

    def nbytes(name):
        dt, dims = shapes.get(name, ("", []))
        b = _DTYPE_BYTES.get(dt, 0)
        n = 1
        for d in dims:
            n *= d
        return n * b if dims or dt in _DTYPE_BYTES else 0

    # classify computations: traffic is counted only at the top level of
    # the entry and while bodies/conds; fusion-internal comps are skipped.
    traffic_comps = {entry} if entry else set()
    for lines in comps.values():
        for ln in lines:
            if _WHILE_RE.search(ln):
                b = _BODY_RE.search(ln)
                c = re.search(r"condition=%?([\w\.\-]+)", ln)
                if b:
                    traffic_comps.add(b.group(1))
                if c:
                    traffic_comps.add(c.group(1))

    flops = 0.0
    traffic = 0.0
    traffic_once = 0.0          # per-computation, unscaled (for eff mult)
    traffic_once_scaled = 0.0
    for cname, lines in comps.items():
        mult = mults.get(cname, 1.0)
        count_traffic = cname in traffic_comps
        for ln in lines:
            dm = _DOT_RE.search(ln)
            if dm:
                out_elems = 1
                for d in _dims(dm.group(3)):
                    out_elems *= d
                lhs_dt, lhs_dims = shapes.get(dm.group(4), ("", []))
                k = 1
                cm = _LHS_CONTRACT_RE.search(ln)
                if cm and lhs_dims:
                    for ci in _dims(cm.group(1)):
                        if ci < len(lhs_dims):
                            k *= lhs_dims[ci]
                flops += 2.0 * out_elems * k * mult
            if count_traffic:
                m = _DEF_RE.match(ln)
                if not m:
                    continue
                # skip zero-cost / separately-accounted instructions
                if re.search(r"= \S+ (parameter|constant|get-tuple-element|"
                             r"tuple|bitcast|while|conditional|all-gather|"
                             r"all-reduce|reduce-scatter|all-to-all|"
                             r"collective-permute|partition-id|after-all|"
                             r"iota)\(", ln.replace("{", " ").replace("]", "] ")) \
                        or re.search(r"\b(parameter|get-tuple-element|tuple|"
                                     r"while|all-gather|all-reduce|"
                                     r"reduce-scatter|all-to-all|"
                                     r"collective-permute)\(", ln):
                    continue
                if "dynamic-update-slice(" in ln:
                    # in-place: read+write only the updated slice (operand 1)
                    ops = _OPERAND_RE.findall(ln.split("(", 1)[1])
                    upd = ops[1] if len(ops) > 1 else None
                    traffic += 2 * nbytes(upd) * mult if upd else 0
                    continue
                w = nbytes(m.group(1))
                r = sum(nbytes(op) for op in _OPERAND_RE.findall(
                    ln.split("(", 1)[1]) if op in shapes) if "(" in ln else 0
                if "dynamic-slice(" in ln:
                    r = w                      # reads only the slice
                traffic += (w + r) * mult
                traffic_once += (w + r)
                traffic_once_scaled += (w + r) * mult

    coll = _collective_bytes_from(comps, mults)
    # effective loop multiplier for memory traffic: XLA's bytes-accessed
    # counts each computation once; weight its total by where the traffic
    # actually sits (entry vs loop bodies) instead of the flops ratio,
    # which misattributes entry-level bytes to deep loops.
    eff_mult = (traffic_once_scaled / traffic_once) if traffic_once else 1.0
    return {"flops": flops, "bytes": traffic, "collectives": coll,
            "traffic_eff_mult": eff_mult}


def _computation_multipliers(comps, entry, default_trip):
    while_edges = defaultdict(list)
    call_edges = defaultdict(list)
    for cname, lines in comps.items():
        for ln in lines:
            if _WHILE_RE.search(ln):
                body = _BODY_RE.search(ln)
                cond = re.search(r"condition=%?([\w\.\-]+)", ln)
                if body:
                    while_edges[cname].append(
                        (body.group(1), cond.group(1) if cond else None))
            else:
                for callee in _CALL_RE.findall(ln):
                    call_edges[cname].append(callee)

    def trip_count(cond_name) -> int:
        if cond_name and cond_name in comps:
            consts = [int(c) for ln in comps[cond_name]
                      for c in _CONST_RE.findall(ln)]
            big = [c for c in consts if c > 1]
            if big:
                return max(big)
        return default_trip

    if entry is None:
        for cname in comps:
            if "main" in cname:
                entry = cname
                break
    if entry is None and comps:
        entry = next(iter(comps))
    mult: Dict[str, float] = defaultdict(float)
    if entry is None:
        return mult
    mult[entry] = 1.0
    work = [entry]
    visited = set()
    while work:
        c = work.pop()
        if c in visited:
            continue
        visited.add(c)
        for body, cond in while_edges.get(c, ()):
            mult[body] = max(mult[body], mult[c] * trip_count(cond))
            work.append(body)
        for callee in call_edges.get(c, ()):
            if callee in comps:
                mult[callee] = max(mult[callee], mult[c])
                work.append(callee)
    return mult


def _collective_bytes_from(comps, mults) -> dict:
    per_kind = defaultdict(float)
    for cname, lines in comps.items():
        m = mults.get(cname, 1.0)
        for ln in lines:
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(-start)?\(", ln):
                    lhs = ln.split(" = ")[0] + " = " + \
                        ln.split(" = ")[1].split(kind)[0] if " = " in ln else ln
                    per_kind[kind] += _shape_bytes(lhs) * m * _MULT.get(kind, 1.0)
                    break
    per_kind["total"] = sum(v for k, v in per_kind.items() if k != "total")
    return dict(per_kind)


def roofline(flops: float, bytes_accessed: float, coll_bytes: float) -> dict:
    """Three roofline terms in seconds (per-chip quantities in, see
    DESIGN.md §8). cost_analysis reports the per-device SPMD module."""
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_accessed / HW["hbm_bw"]
    t_coll = coll_bytes / HW["ici_bw"]
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    terms["bottleneck"] = max(terms, key=lambda k: terms[k]
                              if k.endswith("_s") else -1).replace("_s", "")
    return terms
