"""Production mesh construction (TPU v5e target; CPU placeholder devices
for the dry-run — see dryrun.py which sets XLA_FLAGS before any import).

This module NEVER touches jax device state at import time.
"""
from __future__ import annotations

import jax

from repro.substrate import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = n // model_axis
    return make_mesh((data, model_axis), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,      # FLOP/s per chip
    "hbm_bw": 819e9,                # B/s per chip
    "ici_bw": 50e9,                 # B/s per link
    "hbm_bytes": 16e9,              # HBM capacity per chip
}
