"""Production training launcher.

On real hardware this runs under the production mesh; on this container
it runs any --arch at a --scale-reduced size on the host devices:

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-moe-30b-a3b \
        --reduced --steps 20

Full-size configs on the production mesh are exercised (lower+compile)
by repro.launch.dryrun; this launcher shares the exact same step
construction and sharding rules, so a dry-run pass transfers.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.checkpoint.io import restore_pytree, save_pytree
from repro.configs import ASSIGNED, get_config, smoke
from repro.data.synth_tokens import synthetic_lm_batches
from repro.launch.mesh import make_host_mesh
from repro.substrate import use_mesh
from repro.sharding.rules import (
    batch_pspecs, logits_pspec, named, opt_pspecs, train_state_pspecs,
)
from repro.training.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b", choices=ASSIGNED)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--resume", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = smoke(cfg)
    mesh = make_host_mesh(model_axis=args.model_axis)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    state = init_train_state(jax.random.PRNGKey(0), cfg)
    if args.resume:
        state = restore_pytree(args.resume, state)
        print(f"resumed from {args.resume} at step {int(state.step)}")

    lp = NamedSharding(mesh, logits_pspec(mesh, cfg.padded_vocab, args.seq))
    step = jax.jit(
        make_train_step(cfg, peak_lr=args.lr, warmup=20,
                        total_steps=args.steps,
                        microbatches=args.microbatches, logits_pspec=lp,
                        grads_pspec=named(mesh, opt_pspecs(state.params, mesh))),
        in_shardings=(named(mesh, train_state_pspecs(state, mesh)),
                      named(mesh, batch_pspecs(mesh, args.batch,
                                               cfg.frontend is not None))),
        donate_argnums=(0,))

    fe_shape = ((cfg.n_frontend_tokens, cfg.d_model)
                if cfg.frontend else None)
    batches = synthetic_lm_batches(jax.random.PRNGKey(1), vocab=cfg.vocab,
                                   batch=args.batch, seq=args.seq,
                                   frontend_shape=fe_shape)
    t0 = time.time()
    with use_mesh(mesh):
        for i, batch in zip(range(args.steps), batches):
            state, metrics = step(state, batch)
            if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                      f"grad={float(metrics['grad_norm']):.3f}  "
                      f"{(time.time()-t0)/(i+1):.2f}s/step", flush=True)
    if args.checkpoint:
        save_pytree(args.checkpoint, state)
        print(f"saved checkpoint to {args.checkpoint}")


if __name__ == "__main__":
    main()
