"""Backbone model zoo (see DESIGN.md §3)."""
from repro.models.backbone import (
    forward_features,
    Batch,
    forward_decode,
    forward_prefill,
    forward_train,
    init_caches,
    init_params,
    stack_plan,
)
from repro.models.config import (
    ModelConfig,
    MoeConfig,
    RglruConfig,
    SsdConfig,
)

__all__ = [
    "Batch", "forward_decode", "forward_features", "forward_prefill", "forward_train",
    "init_caches", "init_params", "stack_plan",
    "ModelConfig", "MoeConfig", "RglruConfig", "SsdConfig",
]
