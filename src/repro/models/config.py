"""Model configuration for the backbone zoo.

One frozen dataclass describes every assigned architecture family:
dense decoders, MoE decoders, encoder-decoder (audio), VLM decoders,
hybrid RG-LRU/local-attention (Griffin-style), and Mamba-2 SSD.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Optional, Tuple

ArchType = Literal["dense", "moe", "encdec", "vlm", "hybrid", "ssm"]
MlpAct = Literal["swiglu", "squared_relu", "geglu", "gelu"]
LayerKind = Literal["attn", "local_attn", "recurrent", "ssd", "moe"]


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 0            # routed experts
    top_k: int = 2
    n_shared: int = 0             # shared (always-on) experts
    d_expert: int = 0             # ffn width per expert
    capacity_factor: float = 1.25
    first_k_dense: int = 0        # leading dense layers (deepseek-moe uses 1)
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-4


@dataclass(frozen=True)
class SsdConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    n_heads: int = 0              # H  (d_inner = H * P)
    n_groups: int = 1             # G  (B/C projection groups)
    chunk: int = 128              # SSD chunk length
    conv_kernel: int = 4
    expand: int = 2


@dataclass(frozen=True)
class RglruConfig:
    d_rnn: int = 0                # RG-LRU width (defaults to d_model)
    conv_kernel: int = 4
    c: float = 8.0                # Griffin's fixed recurrence-sharpness const


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: ArchType
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                      # 0 -> d_model // n_heads
    mlp_act: MlpAct = "swiglu"
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    window: int = 0                        # 0 = full attention, else sliding
    # --- family-specific ---
    moe: Optional[MoeConfig] = None
    ssd: Optional[SsdConfig] = None
    rglru: Optional[RglruConfig] = None
    layer_pattern: Tuple[LayerKind, ...] = ()   # hybrid repeat pattern
    # encoder-decoder (audio) — n_layers refers to EACH stack
    n_encoder_layers: int = 0
    cross_attention: bool = False
    # modality frontend stubs (see DESIGN.md §6)
    frontend: Optional[Literal["audio", "vision"]] = None
    n_frontend_tokens: int = 0             # patches / frames fed by the stub
    # numerics
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # vocab rows are padded to this multiple so embedding/head/logits shard
    # cleanly over the (data x model) mesh — production frameworks always
    # pad the vocab. CE masks the pad columns (loss is exact).
    vocab_pad_multiple: int = 256
    # citation for the config provenance
    source: str = ""

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter counting (used for roofline MODEL_FLOPS = 6*N*D)
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        return (self.d_model * self.n_heads * hd          # q
                + 2 * self.d_model * self.n_kv_heads * hd  # k, v
                + self.n_heads * hd * self.d_model)        # o

    def _mlp_params(self, d_ff: int) -> int:
        mult = 3 if self.mlp_act in ("swiglu", "geglu") else 2
        return mult * self.d_model * d_ff

    def _layer_params(self, kind: LayerKind) -> int:
        d = self.d_model
        if kind in ("attn", "local_attn"):
            return self._attn_params() + self._mlp_params(self.d_ff) + 2 * d
        if kind == "moe":
            mc = self.moe
            routed = mc.n_experts * self._mlp_params(mc.d_expert)
            shared = self._mlp_params(mc.n_shared * mc.d_expert)
            router = d * mc.n_experts
            return self._attn_params() + routed + shared + router + 2 * d
        if kind == "recurrent":
            rc = self.rglru
            dr = rc.d_rnn or d
            # in/gate proj, conv, gates, out proj + mlp
            rec = 2 * d * dr + rc.conv_kernel * dr + 2 * dr * dr + 2 * dr + dr * d
            return rec + self._mlp_params(self.d_ff) + 2 * d
        if kind == "ssd":
            sc = self.ssd
            d_in = sc.n_heads * sc.head_dim
            proj_in = d * (2 * d_in + 2 * sc.n_groups * sc.state_dim + sc.n_heads)
            conv = sc.conv_kernel * (d_in + 2 * sc.n_groups * sc.state_dim)
            return proj_in + conv + 2 * sc.n_heads + d_in * d + 2 * d
        raise ValueError(kind)

    def layer_kinds(self) -> Tuple[LayerKind, ...]:
        """The concrete per-layer kind list for the decoder stack."""
        if self.arch_type == "ssm":
            return ("ssd",) * self.n_layers
        if self.arch_type == "hybrid":
            pat = self.layer_pattern or ("recurrent", "recurrent", "local_attn")
            reps = -(-self.n_layers // len(pat))
            return (pat * reps)[: self.n_layers]
        if self.arch_type == "moe":
            fk = self.moe.first_k_dense
            return ("attn",) * fk + ("moe",) * (self.n_layers - fk)
        return ("attn",) * self.n_layers

    def param_count(self) -> int:
        d, v = self.d_model, self.vocab
        n = sum(self._layer_params(k) for k in self.layer_kinds())
        if self.arch_type == "encdec" or self.cross_attention:
            # encoder stack + per-decoder-layer cross attention
            n += self.n_encoder_layers * (
                self._attn_params() + self._mlp_params(self.d_ff) + 2 * d)
            n += self.n_layers * (self._attn_params() + d)
        n += v * d * (1 if self.tie_embeddings else 2)  # embed (+ head)
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.arch_type != "moe":
            return self.param_count()
        mc = self.moe
        full = self.param_count()
        routed_total = (self.n_layers - mc.first_k_dense) * mc.n_experts \
            * self._mlp_params(mc.d_expert)
        routed_active = (self.n_layers - mc.first_k_dense) * mc.top_k \
            * self._mlp_params(mc.d_expert)
        return full - routed_total + routed_active
