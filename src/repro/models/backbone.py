"""Backbone assembly: decoder-only / enc-dec / hybrid / SSM model stacks.

Parameters are stacked along a leading layer axis and the stack runs under
`jax.lax.scan` (keeps HLO size O(1) in depth — required for the 95-layer
dry-runs). Heterogeneous stacks (Griffin 1:2 attention:recurrent pattern,
MoE leading-dense layers) scan over repeating *groups* with any remainder
layers unrolled.

Three entry points per model:
  forward_train   — full-sequence logits (+ MoE aux)
  forward_prefill — causal forward that also returns per-layer caches
  forward_decode  — one-token step against the caches
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import LayerKind, ModelConfig
from repro.models.layers import (
    KVCache, attention_decode, attention_prefill, attention_train,
    init_attention_params, init_kv_cache, rms_norm,
)
from repro.models.mlp import init_mlp_params, mlp_apply
from repro.models.moe import init_moe_params, moe_apply
from repro.models.rglru import (
    RecurrentCache, init_recurrent_cache, init_recurrent_params,
    recurrent_block_decode, recurrent_block_train,
)
from repro.models.ssd import (
    SsdCache, init_ssd_cache, init_ssd_params, ssd_block_decode,
    ssd_block_train,
)


class Batch(NamedTuple):
    """Training / prefill inputs. `frontend` carries stub modality
    embeddings: vision patches (vlm, prepended) or audio frames (encdec,
    encoder input). Fields unused by an arch are None."""
    tokens: jnp.ndarray                      # (B, S) int32
    labels: Optional[jnp.ndarray] = None     # (B, S) int32, -1 = masked
    frontend: Optional[jnp.ndarray] = None   # (B, F, d) modality embeddings


# ---------------------------------------------------------------------------
# per-layer bodies
# ---------------------------------------------------------------------------

def _layer_train(kind: LayerKind, p: dict, x: jnp.ndarray, cfg: ModelConfig,
                 positions: jnp.ndarray, window: int,
                 enc_out: Optional[jnp.ndarray] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x, aux_loss_scalar)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "local_attn"):
        w = window if kind == "local_attn" or window else 0
        h = attention_train(p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                            cfg, positions=positions, window=w)
        x = x + h
        if enc_out is not None:
            h = attention_train(p["cross"], rms_norm(x, p["norm_x"], cfg.norm_eps),
                                cfg, positions=positions, kv_override=enc_out)
            x = x + h
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps),
                          cfg.mlp_act)
    elif kind == "moe":
        h = attention_train(p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                            cfg, positions=positions, window=window)
        x = x + h
        h, moe_aux = moe_apply(p["moe"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        aux = aux + cfg.moe.router_aux_weight * moe_aux["moe_aux_loss"] \
            + cfg.moe.router_z_weight * moe_aux["moe_z_loss"]
        x = x + h
    elif kind == "recurrent":
        h = recurrent_block_train(p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg)
        x = x + h
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps),
                          cfg.mlp_act)
    elif kind == "ssd":
        x = x + ssd_block_train(p["ssd"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg)
    else:
        raise ValueError(kind)
    return x, aux


def _layer_prefill(kind: LayerKind, p: dict, x, cfg, positions, window,
                   cache_len, enc_out=None):
    """Returns (x, cache) — cache type depends on layer kind."""
    if kind in ("attn", "local_attn", "moe"):
        w = window if kind == "local_attn" or window else 0
        h, cache = attention_prefill(p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps),
                                     cfg, positions=positions, window=w,
                                     cache_len=cache_len)
        x = x + h
        if enc_out is not None:
            h = attention_train(p["cross"], rms_norm(x, p["norm_x"], cfg.norm_eps),
                                cfg, positions=positions, kv_override=enc_out)
            x = x + h
        if kind == "moe":
            h, _ = moe_apply(p["moe"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        else:
            h = mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        x = x + h
        return x, cache
    if kind == "recurrent":
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        h = recurrent_block_train(p["rec"], xn, cfg)
        x = x + h
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        # rebuild final state by a single decode-style pass over the tail:
        # for dry-run/serving correctness we recompute state from scratch is
        # expensive; instead reuse scan over gates — simplest faithful option:
        cache = _recurrent_state_from_sequence(p["rec"], xn, cfg)
        return x, cache
    if kind == "ssd":
        xn = rms_norm(x, p["norm1"], cfg.norm_eps)
        h, state = ssd_block_train(p["ssd"], xn, cfg, return_state=True)
        x = x + h
        conv_dim = cfg.ssd.n_heads * cfg.ssd.head_dim \
            + 2 * cfg.ssd.n_groups * cfg.ssd.state_dim
        from repro.models.ssd import _split_proj
        _, xin, Bc, Cc, _ = _split_proj(p["ssd"], xn, cfg)
        xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
        k = cfg.ssd.conv_kernel
        conv = xbc[:, -(k - 1):]
        return x, SsdCache(state=state, conv=conv)
    raise ValueError(kind)


def _recurrent_state_from_sequence(p: dict, xn: jnp.ndarray, cfg: ModelConfig):
    """Final RG-LRU hidden state + conv window after a prefill sequence."""
    from repro.models.rglru import _causal_depthwise_conv, _rglru_gates, rglru_scan
    rc = cfg.rglru
    cdt = xn.dtype
    u_in = jnp.einsum("bsd,de->bse", xn, p["w_x"].astype(cdt))
    u = _causal_depthwise_conv(u_in, p["conv_w"])
    h = rglru_scan(p, u, rc.c)
    k = rc.conv_kernel
    return RecurrentCache(h=h[:, -1].astype(jnp.float32),
                          conv=u_in[:, -(k - 1):])


def _layer_decode(kind: LayerKind, p: dict, x, cfg, pos, cache, window,
                  enc_out=None):
    if kind in ("attn", "local_attn", "moe"):
        w = window if kind == "local_attn" or window else 0
        h, new_cache = attention_decode(p["attn"],
                                        rms_norm(x, p["norm1"], cfg.norm_eps),
                                        cfg, position=pos, cache=cache, window=w)
        x = x + h
        if enc_out is not None:
            h = attention_train(p["cross"], rms_norm(x, p["norm_x"], cfg.norm_eps),
                                cfg, positions=jnp.zeros((1,)), kv_override=enc_out)
            x = x + h
        if kind == "moe":
            h, _ = moe_apply(p["moe"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg)
        else:
            h = mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        return x + h, new_cache
    if kind == "recurrent":
        h, new_cache = recurrent_block_decode(
            p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, cache)
        x = x + h
        x = x + mlp_apply(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg.mlp_act)
        return x, new_cache
    if kind == "ssd":
        h, new_cache = ssd_block_decode(
            p["ssd"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, cache)
        return x + h, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# layer param/cache initializers
# ---------------------------------------------------------------------------

def _init_layer(key, kind: LayerKind, cfg: ModelConfig, dtype,
                cross: bool = False) -> dict:
    keys = jax.random.split(key, 6)
    d = cfg.d_model
    p: dict = {"norm1": jnp.zeros((d,), dtype)}
    if kind in ("attn", "local_attn"):
        p["attn"] = init_attention_params(keys[0], cfg, dtype)
        p["mlp"] = init_mlp_params(keys[1], cfg, cfg.d_ff, dtype)
        p["norm2"] = jnp.zeros((d,), dtype)
    elif kind == "moe":
        p["attn"] = init_attention_params(keys[0], cfg, dtype)
        p["moe"] = init_moe_params(keys[1], cfg, dtype)
        p["norm2"] = jnp.zeros((d,), dtype)
    elif kind == "recurrent":
        p["rec"] = init_recurrent_params(keys[0], cfg, dtype)
        p["mlp"] = init_mlp_params(keys[1], cfg, cfg.d_ff, dtype)
        p["norm2"] = jnp.zeros((d,), dtype)
    elif kind == "ssd":
        p["ssd"] = init_ssd_params(keys[0], cfg, dtype)
    if cross:
        p["cross"] = init_attention_params(keys[2], cfg, dtype)
        p["norm_x"] = jnp.zeros((d,), dtype)
    return p


def _init_layer_cache(kind: LayerKind, cfg: ModelConfig, batch: int,
                      cache_len: int, window: int):
    if kind in ("attn", "moe"):
        L = min(cache_len, window) if window else cache_len
        return init_kv_cache(batch, L, cfg.n_kv_heads, cfg.resolved_head_dim,
                             dtype=jnp.dtype(cfg.compute_dtype))
    if kind == "local_attn":
        L = min(cache_len, window or cache_len)
        return init_kv_cache(batch, L, cfg.n_kv_heads, cfg.resolved_head_dim,
                             dtype=jnp.dtype(cfg.compute_dtype))
    if kind == "recurrent":
        return init_recurrent_cache(batch, cfg)
    if kind == "ssd":
        return init_ssd_cache(batch, cfg)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack structure: (scan groups, remainder tail)
# ---------------------------------------------------------------------------

def stack_plan(cfg: ModelConfig) -> Tuple[Tuple[LayerKind, ...], int, Tuple[LayerKind, ...]]:
    """Returns (group pattern, n_scan_groups, tail kinds).

    Homogeneous stacks scan one-layer groups; Griffin scans its 3-layer
    pattern; MoE scans the MoE layers with the leading dense layers in the
    (unrolled) *head*, which we represent as tail_kinds applied FIRST when
    `head=True` (see forward)."""
    kinds = cfg.layer_kinds()
    if cfg.arch_type == "hybrid":
        pat = cfg.layer_pattern or ("recurrent", "recurrent", "local_attn")
        n_groups = len(kinds) // len(pat)
        tail = kinds[n_groups * len(pat):]
        return tuple(pat), n_groups, tuple(tail)
    if cfg.arch_type == "moe" and cfg.moe.first_k_dense:
        fk = cfg.moe.first_k_dense
        return ("moe",), len(kinds) - fk, ("attn",) * fk
    return (kinds[0],), len(kinds), ()


def _moe_head_first(cfg: ModelConfig) -> bool:
    return cfg.arch_type == "moe" and bool(cfg.moe.first_k_dense)


def init_params(key, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.param_dtype)
    pat, n_groups, tail = stack_plan(cfg)
    k_emb, k_head, k_stack, k_tail, k_enc = jax.random.split(key, 5)

    def one_group(k):
        ks = jax.random.split(k, len(pat))
        return {f"p{i}": _init_layer(ks[i], kind, cfg, dtype,
                                     cross=cfg.cross_attention)
                for i, kind in enumerate(pat)}

    stacked = jax.vmap(one_group)(jax.random.split(k_stack, n_groups))
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.padded_vocab, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "layers": stacked,
    }
    if not cfg.tie_embeddings:
        params["head"] = (jax.random.normal(k_head,
                                            (cfg.d_model, cfg.padded_vocab))
                          * cfg.d_model ** -0.5).astype(dtype)
    if tail:
        ks = jax.random.split(k_tail, len(tail))
        params["tail"] = [_init_layer(ks[i], kind, cfg, dtype,
                                      cross=cfg.cross_attention)
                          for i, kind in enumerate(tail)]
    if cfg.arch_type == "encdec":
        ks = jax.random.split(k_enc, 2)
        enc_cfg = cfg  # same dims for encoder stack
        def enc_group(k):
            return {"p0": _init_layer(k, "attn", enc_cfg, dtype, cross=False)}
        params["encoder"] = {
            "layers": jax.vmap(enc_group)(
                jax.random.split(ks[0], cfg.n_encoder_layers)),
            "final_norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------

def _embed(params, cfg, tokens):
    cdt = jnp.dtype(cfg.compute_dtype)
    return jnp.take(params["embed"], tokens, axis=0).astype(cdt)


def _unembed(params, cfg, x):
    head = params["head"] if "head" in params else params["embed"].T
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


def _encoder_forward(params, cfg, frames):
    """Bidirectional encoder over stub audio-frame embeddings (B, F, d)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = frames.astype(cdt)
    positions = jnp.arange(x.shape[1])
    enc = params["encoder"]

    def body(x, lp):
        h = attention_train(lp["p0"]["attn"],
                            rms_norm(x, lp["p0"]["norm1"], cfg.norm_eps), cfg,
                            positions=positions, causal=False)
        x = x + h
        x = x + mlp_apply(lp["p0"]["mlp"],
                          rms_norm(x, lp["p0"]["norm2"], cfg.norm_eps),
                          cfg.mlp_act)
        return x, None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return rms_norm(x, enc["final_norm"], cfg.norm_eps)


def _decoder_stack_train(params, cfg, x, positions, enc_out, remat: bool):
    pat, n_groups, tail = stack_plan(cfg)
    aux_total = jnp.zeros((), jnp.float32)

    def tail_pass(x, aux_total):
        for lp, kind in zip(params.get("tail", []), tail):
            x, aux = _layer_train(kind, lp, x, cfg, positions, cfg.window,
                                  enc_out)
            aux_total = aux_total + aux
        return x, aux_total

    if _moe_head_first(cfg):
        x, aux_total = tail_pass(x, aux_total)   # leading dense layers

    def group(carry, gp):
        x, aux_total = carry
        for i, kind in enumerate(pat):
            x, aux = _layer_train(kind, gp[f"p{i}"], x, cfg, positions,
                                  cfg.window, enc_out)
            aux_total = aux_total + aux
        return (x, aux_total), None

    group_fn = jax.checkpoint(group) if remat else group
    (x, aux_total), _ = jax.lax.scan(group_fn, (x, aux_total), params["layers"])

    if not _moe_head_first(cfg):
        x, aux_total = tail_pass(x, aux_total)   # Griffin remainder layers
    return x, aux_total


def forward_train(params, cfg: ModelConfig, batch: Batch, *,
                  remat: bool = True):
    """Full-sequence forward. Returns (logits (B,S,V), aux_loss)."""
    x = _embed(params, cfg, batch.tokens)
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encoder_forward(params, cfg, batch.frontend)
    elif cfg.arch_type == "vlm" and batch.frontend is not None:
        x = jnp.concatenate([batch.frontend.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, aux = _decoder_stack_train(params, cfg, x, positions, enc_out, remat)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.arch_type == "vlm" and batch.frontend is not None:
        x = x[:, batch.frontend.shape[1]:]       # loss only on token positions
    logits = _unembed(params, cfg, x)
    return logits, aux


def forward_features(params, cfg: ModelConfig, batch: Batch, *,
                     remat: bool = False) -> jnp.ndarray:
    """Final-norm hidden states (B, S, d) — the feature interface used by
    multitask.sparse_probe (DSML heads on any backbone)."""
    x = _embed(params, cfg, batch.tokens)
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encoder_forward(params, cfg, batch.frontend)
    elif cfg.arch_type == "vlm" and batch.frontend is not None:
        x = jnp.concatenate([batch.frontend.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    x, _ = _decoder_stack_train(params, cfg, x, positions, enc_out, remat)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward_prefill(params, cfg: ModelConfig, batch: Batch, *,
                    cache_len: Optional[int] = None):
    """Causal prompt pass. Returns (last-position logits, caches pytree)."""
    x = _embed(params, cfg, batch.tokens)
    enc_out = None
    if cfg.arch_type == "encdec":
        enc_out = _encoder_forward(params, cfg, batch.frontend)
    elif cfg.arch_type == "vlm" and batch.frontend is not None:
        x = jnp.concatenate([batch.frontend.astype(x.dtype), x], axis=1)
    positions = jnp.arange(x.shape[1])
    pat, n_groups, tail = stack_plan(cfg)
    cl = cache_len or x.shape[1]

    tail_caches = []

    def tail_pass(x):
        for lp, kind in zip(params.get("tail", []), tail):
            x, c = _layer_prefill(kind, lp, x, cfg, positions, cfg.window, cl,
                                  enc_out)
            tail_caches.append(c)
        return x

    if _moe_head_first(cfg):
        x = tail_pass(x)

    def group(x, gp):
        caches = {}
        for i, kind in enumerate(pat):
            x, c = _layer_prefill(kind, gp[f"p{i}"], x, cfg, positions,
                                  cfg.window, cl, enc_out)
            caches[f"p{i}"] = c
        return x, caches

    x, stack_caches = jax.lax.scan(group, x, params["layers"])

    if not _moe_head_first(cfg):
        x = tail_pass(x)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x[:, -1:])
    caches = {"stack": stack_caches, "tail": tail_caches, "enc_out": enc_out}
    return logits, caches


def forward_decode(params, cfg: ModelConfig, token: jnp.ndarray,
                   pos: jnp.ndarray, caches: dict):
    """One decode step. token: (B, 1) int32; pos: scalar int32.

    Returns (logits (B,1,V), new caches)."""
    x = _embed(params, cfg, token)
    enc_out = caches.get("enc_out")
    pat, n_groups, tail = stack_plan(cfg)
    new_tail = []

    def tail_pass(x):
        for lp, kind, c in zip(params.get("tail", []), tail, caches["tail"]):
            x, nc = _layer_decode(kind, lp, x, cfg, pos, c, cfg.window, enc_out)
            new_tail.append(nc)
        return x

    if _moe_head_first(cfg):
        x = tail_pass(x)

    def group(x, scanned):
        gp, gc = scanned
        new_c = {}
        for i, kind in enumerate(pat):
            x, nc = _layer_decode(kind, gp[f"p{i}"], x, cfg, pos, gc[f"p{i}"],
                                  cfg.window, enc_out)
            new_c[f"p{i}"] = nc
        return x, new_c

    x, new_stack = jax.lax.scan(group, x, (params["layers"], caches["stack"]))

    if not _moe_head_first(cfg):
        x = tail_pass(x)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, cfg, x)
    if cfg.padded_vocab != cfg.vocab:
        pad_mask = jnp.arange(cfg.padded_vocab) < cfg.vocab
        logits = jnp.where(pad_mask, logits, jnp.finfo(logits.dtype).min)
    return logits, {"stack": new_stack, "tail": new_tail, "enc_out": enc_out}


def init_caches(cfg: ModelConfig, batch: int, cache_len: int) -> dict:
    """Decode caches shaped like forward_prefill's output (fresh/empty)."""
    pat, n_groups, tail = stack_plan(cfg)

    def one_group(_):
        return {f"p{i}": _init_layer_cache(kind, cfg, batch, cache_len,
                                           cfg.window)
                for i, kind in enumerate(pat)}

    stack = jax.tree.map(
        lambda *xs: jnp.stack(xs), *[one_group(g) for g in range(n_groups)]
    ) if n_groups > 1 else jax.tree.map(lambda x: x[None], one_group(0))
    tail_caches = [_init_layer_cache(k, cfg, batch, cache_len, cfg.window)
                   for k in tail]
    enc_out = None
    if cfg.arch_type == "encdec":
        cdt = jnp.dtype(cfg.compute_dtype)
        enc_out = jnp.zeros((batch, cfg.n_frontend_tokens, cfg.d_model), cdt)
    return {"stack": stack, "tail": tail_caches, "enc_out": enc_out}
