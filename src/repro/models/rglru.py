"""Griffin-style recurrent block: temporal conv + RG-LRU (RecurrentGemma).

The RG-LRU recurrence (Griffin, arXiv:2402.19427):

    r_t = sigmoid(W_a u_t + b_a)            recurrence gate
    i_t = sigmoid(W_i u_t + b_i)            input gate
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

Training/prefill evaluates the linear recurrence with an associative scan
(log-depth on TPU); decode carries (h, conv window) state.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


class RecurrentCache(NamedTuple):
    h: jnp.ndarray          # (B, d_rnn) RG-LRU hidden state
    conv: jnp.ndarray       # (B, kernel-1, d_rnn) trailing conv inputs


def _causal_depthwise_conv(u: jnp.ndarray, w: jnp.ndarray,
                           carry: jnp.ndarray | None = None) -> jnp.ndarray:
    """u: (B, S, D), w: (k, D) depthwise causal conv; carry: (B, k-1, D)."""
    k = w.shape[0]
    if carry is None:
        carry = jnp.zeros((u.shape[0], k - 1, u.shape[2]), u.dtype)
    ext = jnp.concatenate([carry.astype(u.dtype), u], axis=1)
    out = sum(ext[:, i:i + u.shape[1]] * w[i].astype(u.dtype) for i in range(k))
    return out


def _rglru_gates(p: dict, u: jnp.ndarray, c: float):
    f32 = jnp.float32
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u.astype(f32),
                                  p["w_a"].astype(f32)) + p["b_a"].astype(f32))
    i = jax.nn.sigmoid(jnp.einsum("...d,de->...e", u.astype(f32),
                                  p["w_i"].astype(f32)) + p["b_i"].astype(f32))
    log_a = -c * jax.nn.softplus(p["lam"].astype(f32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(f32))
    return a, b


def rglru_scan(p: dict, u: jnp.ndarray, c: float) -> jnp.ndarray:
    """Full-sequence RG-LRU via associative scan. u: (B, S, D)."""
    a, b = _rglru_gates(p, u, c)

    def combine(left, right):
        al, bl = left
        ar, br = right
        return ar * al, ar * bl + br

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h.astype(u.dtype)


def rglru_step(p: dict, u: jnp.ndarray, h: jnp.ndarray, c: float):
    """One decode step. u: (B, 1, D), h: (B, D) -> (y (B,1,D), h')."""
    a, b = _rglru_gates(p, u, c)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new[:, None].astype(u.dtype), h_new.astype(jnp.float32)


def recurrent_block_train(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Griffin recurrent block, full sequence. x: (B, S, d_model)."""
    rc = cfg.rglru
    cdt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(cdt)))
    u = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(cdt))
    u = _causal_depthwise_conv(u, p["conv_w"])
    h = rglru_scan(p, u, rc.c)
    return jnp.einsum("bse,ed->bsd", h * gate, p["w_o"].astype(cdt))


def recurrent_block_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                           cache: RecurrentCache) -> Tuple[jnp.ndarray, RecurrentCache]:
    """One-token decode. x: (B, 1, d_model)."""
    rc = cfg.rglru
    cdt = x.dtype
    gate = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_gate"].astype(cdt)))
    u_in = jnp.einsum("bsd,de->bse", x, p["w_x"].astype(cdt))
    u = _causal_depthwise_conv(u_in, p["conv_w"], carry=cache.conv)
    conv_new = jnp.concatenate([cache.conv[:, 1:], u_in.astype(cache.conv.dtype)],
                               axis=1)
    y, h_new = rglru_step(p, u, cache.h, rc.c)
    out = jnp.einsum("bse,ed->bsd", y * gate, p["w_o"].astype(cdt))
    return out, RecurrentCache(h=h_new, conv=conv_new)


def init_recurrent_cache(batch: int, cfg: ModelConfig) -> RecurrentCache:
    rc = cfg.rglru
    dr = rc.d_rnn or cfg.d_model
    cdt = jnp.dtype(cfg.compute_dtype)
    return RecurrentCache(
        h=jnp.zeros((batch, dr), jnp.float32),
        conv=jnp.zeros((batch, rc.conv_kernel - 1, dr), cdt),
    )


def init_recurrent_params(key, cfg: ModelConfig, dtype) -> dict:
    rc = cfg.rglru
    d = cfg.d_model
    dr = rc.d_rnn or d
    keys = jax.random.split(key, 6)
    return {
        "w_gate": (jax.random.normal(keys[0], (d, dr)) * d ** -0.5).astype(dtype),
        "w_x": (jax.random.normal(keys[1], (d, dr)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(keys[2], (rc.conv_kernel, dr))
                   * rc.conv_kernel ** -0.5).astype(dtype),
        "w_a": (jax.random.normal(keys[3], (dr, dr)) * dr ** -0.5).astype(dtype),
        "b_a": jnp.zeros((dr,), dtype),
        "w_i": (jax.random.normal(keys[4], (dr, dr)) * dr ** -0.5).astype(dtype),
        "b_i": jnp.zeros((dr,), dtype),
        # Lambda init so that a ~ U[0.9, 0.999]^c at r=1 (Griffin appendix)
        "lam": jnp.linspace(0.1, 2.0, dr).astype(dtype),
        "w_o": (jax.random.normal(keys[5], (dr, d)) * dr ** -0.5).astype(dtype),
    }
