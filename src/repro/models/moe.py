"""Mixture-of-Experts layer: shared + routed experts, top-k router.

Dispatch is gather/scatter-based (capacity-bounded, token-dropping), the
EP-friendly formulation: tokens are gathered into dense (E, C, d) expert
batches, experts run as one batched einsum on stacked weights (sharded
over the `model` mesh axis = expert parallelism), and results scatter-add
back with router combine weights. GSPMD inserts the all-to-alls at the
data<->expert sharding boundary.
"""
from __future__ import annotations

import math
from contextlib import contextmanager
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

# Optional sharding constraints for the dispatch/combine boundary, set by
# the launcher (§Perf H2): without them GSPMD replicates the (E, C, d)
# expert batches across the data axis.
_MOE_SHARDING: dict | None = None


@contextmanager
def moe_sharding(*, expert_batch, tokens):
    """expert_batch: spec for (E, C, d) tensors; tokens: spec for (T, d)."""
    global _MOE_SHARDING
    prev = _MOE_SHARDING
    _MOE_SHARDING = {"expert_batch": expert_batch, "tokens": tokens}
    try:
        yield
    finally:
        _MOE_SHARDING = prev


def _wsc(x, key):
    if _MOE_SHARDING is not None and _MOE_SHARDING.get(key) is not None:
        return jax.lax.with_sharding_constraint(x, _MOE_SHARDING[key])
    return x


def _expert_ffn(p: dict, xe: jnp.ndarray, act: str) -> jnp.ndarray:
    """xe: (E, C, d) -> (E, C, d) with stacked per-expert weights."""
    cdt = xe.dtype
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].astype(cdt))
        u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(cdt))
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].astype(cdt))
        h = jnp.square(jax.nn.relu(h)) if act == "squared_relu" else jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cdt))


def moe_apply(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, d). Returns (out, aux) with router load-balance metrics."""
    mc = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, K = mc.n_experts, mc.top_k
    C = max(1, math.ceil(T * K * mc.capacity_factor / E))
    xf = x.reshape(T, d)

    # ---- router (float32 for numerics) ----
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                        p["router"].astype(jnp.float32))           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                          # (T, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- capacity-bounded slot assignment ----
    flat_e = top_e.reshape(-1)                                      # (T*K,)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)             # (T*K, E)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              flat_e[:, None], axis=1)[:, 0]        # (T*K,)
    tok = jnp.arange(T * K) // K

    idx_table = jnp.zeros((E, C), jnp.int32).at[flat_e, pos].set(
        tok, mode="drop")                                           # (E, C)
    w_table = jnp.zeros((E, C), jnp.float32).at[flat_e, pos].set(
        flat_w, mode="drop")
    valid = jnp.zeros((E, C), bool).at[flat_e, pos].set(True, mode="drop")

    # ---- expert compute on dense (E, C, d) batches ----
    xf = _wsc(xf, "tokens")
    xe = jnp.take(xf, idx_table.reshape(-1), axis=0).reshape(E, C, d)
    xe = _wsc(xe * valid[..., None].astype(xe.dtype), "expert_batch")
    ye = _wsc(_expert_ffn(p["experts"], xe, cfg.mlp_act), "expert_batch")

    # ---- combine (scatter-add with router weights) ----
    contrib = ye * (w_table * valid)[..., None].astype(ye.dtype)
    out = jnp.zeros((T, d), ye.dtype).at[idx_table.reshape(-1)].add(
        contrib.reshape(-1, d))
    out = _wsc(out, "tokens")

    # ---- shared (always-on) experts ----
    if mc.n_shared:
        from repro.models.mlp import mlp_apply
        out = out + mlp_apply(p["shared"], xf[None], cfg.mlp_act)[0]

    # ---- router losses (Switch-style balance + z-loss) ----
    f = jnp.mean(jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0)
    pbar = jnp.mean(probs, axis=0)
    aux_loss = E * jnp.sum(f * pbar)
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(valid) / (T * K)
    aux = {"moe_aux_loss": aux_loss, "moe_z_loss": z_loss,
           "moe_drop_frac": dropped}
    return out.reshape(B, S, d), aux


def init_moe_params(key, cfg: ModelConfig, dtype) -> dict:
    from repro.models.mlp import init_mlp_params
    mc = cfg.moe
    d, E, f = cfg.d_model, mc.n_experts, mc.d_expert
    keys = jax.random.split(key, 6)
    si, so = d ** -0.5, f ** -0.5
    experts = {
        "w_up": (jax.random.normal(keys[0], (E, d, f)) * si).astype(dtype),
        "w_down": (jax.random.normal(keys[1], (E, f, d)) * so).astype(dtype),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        experts["w_gate"] = (jax.random.normal(keys[2], (E, d, f)) * si).astype(dtype)
    p = {
        "router": (jax.random.normal(keys[3], (d, E)) * si).astype(jnp.float32),
        "experts": experts,
    }
    if mc.n_shared:
        p["shared"] = init_mlp_params(keys[4], cfg, mc.n_shared * f, dtype)
    return p
