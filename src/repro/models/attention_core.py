"""Blockwise (flash-style) attention with a custom VJP, in pure JAX.

Materializing (S, T) score matrices is impossible at 32k+ context
(hundreds of GB per layer); this module computes attention with online
softmax over key/value blocks, O(S) memory, and a Flash-2-style backward
that recomputes scores per block from the saved (out, lse).

Layouts (GQA-grouped):
  q: (B, K, G, S, H)   k, v: (B, K, T, H)
Masking is positional: q_pos (S,), k_pos (T,), k_valid (T,) handle
causality, sliding windows, ring-buffer caches and padding uniformly.

This is also the pure-jnp oracle for kernels/flash_attention.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _block_mask(q_pos, k_pos, k_valid, causal: bool, window: int):
    """(S, Tb) boolean mask for one key block."""
    m = k_valid[None, :]
    if causal:
        m = m & (k_pos[None, :] <= q_pos[:, None])
    if window:
        m = m & (k_pos[None, :] > q_pos[:, None] - window)
    return m


def _pad_to(x, mult, axis, value=0):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def flash_attention_grouped(q, k, v, q_pos, k_pos, k_valid,
                            causal: bool = True, window: int = 0,
                            block: int = 1024):
    out, _ = _flash_fwd(q, k, v, q_pos, k_pos, k_valid, causal, window, block)
    return out


def _flash_fwd(q, k, v, q_pos, k_pos, k_valid, causal, window, block):
    B, K, G, S, H = q.shape
    T = k.shape[2]
    blk = min(block, T)
    scale = 1.0 / jnp.sqrt(H).astype(jnp.float32)

    kp = _pad_to(k, blk, 2)
    vp = _pad_to(v, blk, 2)
    kpos = _pad_to(k_pos, blk, 0, value=-1)
    kval = _pad_to(k_valid, blk, 0, value=False)
    nb = kp.shape[2] // blk

    ks = kp.reshape(B, K, nb, blk, H).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(B, K, nb, blk, H).transpose(2, 0, 1, 3, 4)
    kps = kpos.reshape(nb, blk)
    kvs = kval.reshape(nb, blk)

    def body(carry, xs):
        m, l, acc = carry
        k_j, v_j, kp_j, kv_j = xs
        s = jnp.einsum("bkgsh,bkth->bkgst", q, k_j).astype(jnp.float32) * scale
        mask = _block_mask(q_pos, kp_j, kv_j, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        # mask multiply guards fully-masked rows (exp(-inf - -inf) == 1)
        p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
        l_new = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,bkth->bkgsh", p.astype(v_j.dtype), v_j)
        acc_new = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, K, G, S), jnp.float32)
    a0 = jnp.zeros((B, K, G, S, H), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps, kvs))

    safe_l = jnp.maximum(l, 1e-30)
    out = (acc / safe_l[..., None]).astype(q.dtype)
    out = jnp.where((l > 0)[..., None], out, 0)
    lse = m + jnp.log(safe_l)
    return out, lse


def _flash_fwd_vjp(q, k, v, q_pos, k_pos, k_valid, causal, window, block):
    out, lse = _flash_fwd(q, k, v, q_pos, k_pos, k_valid, causal, window, block)
    return out, (q, k, v, q_pos, k_pos, k_valid, out, lse)


def _flash_bwd(causal, window, block, res, dout):
    q, k, v, q_pos, k_pos, k_valid, out, lse = res
    B, K, G, S, H = q.shape
    T = k.shape[2]
    blk = min(block, T)
    scale = 1.0 / jnp.sqrt(H).astype(jnp.float32)
    f32 = jnp.float32

    D = jnp.sum(dout.astype(f32) * out.astype(f32), axis=-1)      # (B,K,G,S)

    kp = _pad_to(k, blk, 2)
    vp = _pad_to(v, blk, 2)
    kpos = _pad_to(k_pos, blk, 0, value=-1)
    kval = _pad_to(k_valid, blk, 0, value=False)
    nb = kp.shape[2] // blk
    ks = kp.reshape(B, K, nb, blk, H).transpose(2, 0, 1, 3, 4)
    vs = vp.reshape(B, K, nb, blk, H).transpose(2, 0, 1, 3, 4)
    kps = kpos.reshape(nb, blk)
    kvs = kval.reshape(nb, blk)

    def block_terms(k_j, v_j, kp_j, kv_j):
        s = jnp.einsum("bkgsh,bkth->bkgst", q, k_j).astype(f32) * scale
        mask = _block_mask(q_pos, kp_j, kv_j, causal, window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        p = jnp.exp(s - lse[..., None]) * mask[None, None, None]   # (B,K,G,S,Tb)
        dp = jnp.einsum("bkgsh,bkth->bkgst", dout, v_j).astype(f32)
        ds = p * (dp - D[..., None]) * scale
        return p, ds

    # dq accumulates over kv blocks
    def dq_body(dq, xs):
        p, ds = block_terms(*xs)
        dq_new = dq + jnp.einsum("bkgst,bkth->bkgsh",
                                 ds.astype(k.dtype), xs[0]).astype(f32)
        return dq_new, None

    dq0 = jnp.zeros((B, K, G, S, H), f32)
    dq, _ = jax.lax.scan(dq_body, dq0, (ks, vs, kps, kvs))

    # dk/dv per kv block (no cross-block coupling)
    def dkv_body(_, xs):
        k_j, v_j = xs[0], xs[1]
        p, ds = block_terms(*xs)
        dk_j = jnp.einsum("bkgst,bkgsh->bkth", ds.astype(q.dtype), q)
        dv_j = jnp.einsum("bkgst,bkgsh->bkth", p.astype(dout.dtype), dout)
        return None, (dk_j, dv_j)

    _, (dks, dvs) = jax.lax.scan(dkv_body, None, (ks, vs, kps, kvs))
    dk = dks.transpose(1, 2, 0, 3, 4).reshape(B, K, nb * blk, H)[:, :, :T]
    dv = dvs.transpose(1, 2, 0, 3, 4).reshape(B, K, nb * blk, H)[:, :, :T]

    return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            None, None, None)


flash_attention_grouped.defvjp(_flash_fwd_vjp, _flash_bwd)


def flash_attention(q, k, v, *, q_pos, k_pos,
                    k_valid: Optional[jnp.ndarray] = None,
                    causal: bool = True, window: int = 0,
                    block: int = 1024):
    """Standard layout wrapper. q: (B,S,N,H), k/v: (B,T,K,H) -> (B,S,N,H)."""
    B, S, N, H = q.shape
    K = k.shape[2]
    G = N // K
    qg = q.reshape(B, S, K, G, H).transpose(0, 2, 3, 1, 4)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    if k_valid is None:
        k_valid = jnp.ones((k.shape[1],), bool)
    out = flash_attention_grouped(qg, kt, vt,
                                  q_pos.astype(jnp.int32),
                                  k_pos.astype(jnp.int32), k_valid,
                                  causal, window, block)
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, N, H)
