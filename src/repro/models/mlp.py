"""Feed-forward variants: SwiGLU (llama), squared-ReLU (nemotron), GELU/GeGLU."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


def mlp_apply(p: dict, x: jnp.ndarray, act: str) -> jnp.ndarray:
    cdt = x.dtype
    if act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(cdt))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt))
        g = jax.nn.silu(g) if act == "swiglu" else jax.nn.gelu(g)
        h = g * u
    else:
        h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(cdt))
        if act == "squared_relu":
            h = jnp.square(jax.nn.relu(h))
        elif act == "gelu":
            h = jax.nn.gelu(h)
        else:
            raise ValueError(act)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(cdt))


def init_mlp_params(key, cfg: ModelConfig, d_ff: int, dtype) -> dict:
    d = cfg.d_model
    k1, k2, k3 = jax.random.split(key, 3)
    si, so = d ** -0.5, d_ff ** -0.5
    p = {
        "w_up": (jax.random.normal(k2, (d, d_ff)) * si).astype(dtype),
        "w_down": (jax.random.normal(k3, (d_ff, d)) * so).astype(dtype),
    }
    if cfg.mlp_act in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(k1, (d, d_ff)) * si).astype(dtype)
    return p
