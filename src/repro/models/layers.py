"""Shared transformer layers: RMSNorm, RoPE, GQA attention (full, sliding-
window, cross) with training, prefill and single-token decode paths.

All functions are pure; parameters are plain pytrees (dicts of arrays).
Matmuls run in the config compute dtype (bf16 on TPU); softmax and norms
accumulate in float32.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NEG_INF = -1e30
# use blockwise attention once the score matrix would exceed ~2k x 2k
FLASH_THRESHOLD = 2048 * 2048


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Statistics in f32, application in the compute dtype.

    Applying in bf16 keeps the backward pass (and therefore the per-layer
    tensor-parallel all-reduces of dx) in bf16 — computing the whole norm
    in f32 doubled every TP collective (§Perf H3)."""
    dtype = x.dtype
    # square in the compute dtype, ACCUMULATE in f32: a full f32 copy of x
    # would get hoisted out of the backward scan as an O(L*B*S*d) buffer
    # (12.8 GB/chip on deepseek-67b — §Perf H3 iter 2)
    # the explicit astype puts a convert on the AD path, so the cotangent
    # of x comes back DOWNCAST to bf16 (mean(..., dtype=f32) alone leaves
    # dx in f32, and XLA then saves the whole residual stack in f32)
    var = jnp.mean(jnp.square(x).astype(jnp.float32), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(dtype)
    return x * inv * (1.0 + scale.astype(dtype))


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, N, H); positions: (B, S) or (S,)."""
    h = x.shape[-1]
    half = h // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs          # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


class KVCache(NamedTuple):
    """Decode-time attention cache.

    k, v: (B, S_cache, K, H). For sliding-window layers, S_cache == window
    and the buffer is a ring indexed by position % window; `slot_pos`
    records the absolute position stored in each slot (-1 = empty).
    """
    k: jnp.ndarray
    v: jnp.ndarray
    slot_pos: jnp.ndarray      # (S_cache,) int32


def init_kv_cache(batch: int, length: int, n_kv: int, head_dim: int,
                  dtype=jnp.bfloat16) -> KVCache:
    return KVCache(
        k=jnp.zeros((batch, length, n_kv, head_dim), dtype),
        v=jnp.zeros((batch, length, n_kv, head_dim), dtype),
        slot_pos=jnp.full((length,), -1, jnp.int32),
    )


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray) -> jnp.ndarray:
    """q: (B,S,N,H), k: (B,T,K,H) -> scores (B,K,G,S,T) with N = K*G."""
    B, S, N, H = q.shape
    K = k.shape[2]
    G = N // K
    qg = q.reshape(B, S, K, G, H)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k) / jnp.sqrt(H).astype(q.dtype)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """probs: (B,K,G,S,T), v: (B,T,K,H) -> (B,S,N,H)."""
    B, K, G, S, T = probs.shape
    out = jnp.einsum("bkgst,btkh->bskgh", probs, v)
    return out.reshape(B, S, K * G, -1)


def _masked_softmax(scores: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows with no valid key (fully masked) -> zeros, not NaN
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    return probs


def attention_train(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                    positions: jnp.ndarray, causal: bool = True,
                    window: int = 0,
                    kv_override: Optional[jnp.ndarray] = None,
                    kv_mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Full-sequence attention (training / encoder / prefill compute).

    kv_override: (B, T, d) encoder output for cross-attention (then causal
    and window are ignored and kv_mask (B, T) masks padding).
    """
    cdt = x.dtype
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(cdt))
    src = x if kv_override is None else kv_override.astype(cdt)
    k = jnp.einsum("btd,dkh->btkh", src, p["wk"].astype(cdt))
    v = jnp.einsum("btd,dkh->btkh", src, p["wv"].astype(cdt))

    if kv_override is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

    # Long sequences: blockwise (flash) attention — O(S) memory instead of
    # materializing the (S, T) score matrix (impossible at 32k context).
    S_q, T_k = q.shape[1], k.shape[1]
    if kv_override is None and S_q * T_k >= FLASH_THRESHOLD and S_q > 1:
        from repro.models.attention_core import flash_attention
        pos1d = positions if positions.ndim == 1 else positions[0]
        out = flash_attention(q, k, v, q_pos=pos1d, k_pos=pos1d,
                              causal=causal, window=window)
        return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(cdt))

    scores = _gqa_scores(q, k)                                  # (B,K,G,S,T)
    S, T = scores.shape[-2], scores.shape[-1]
    if kv_override is not None:
        mask = jnp.ones((S, T), bool) if kv_mask is None \
            else kv_mask[:, None, None, None, :]
    else:
        i = jnp.arange(S)[:, None]
        j = jnp.arange(T)[None, :]
        mask = jnp.ones((S, T), bool)
        if causal:
            mask &= j <= i
        if window:
            mask &= j > i - window
    probs = _masked_softmax(scores, mask).astype(cdt)
    out = _gqa_out(probs, v)
    return jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(cdt))


def attention_prefill(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                      positions: jnp.ndarray, window: int = 0,
                      cache_len: Optional[int] = None):
    """Causal attention over the prompt; returns (out, KVCache)."""
    cdt = x.dtype
    B, S, _ = x.shape
    out = attention_train(p, x, cfg, positions=positions, causal=True,
                          window=window)
    k = jnp.einsum("btd,dkh->btkh", x, p["wk"].astype(cdt))
    v = jnp.einsum("btd,dkh->btkh", x, p["wv"].astype(cdt))
    k = rope(k, positions, cfg.rope_theta)
    L = cache_len or S
    if window:
        L = min(L, window)
    pos1d = positions if positions.ndim == 1 else positions[0]
    if not window:
        assert L >= S, f"cache_len {L} < seq {S} needs a sliding window"
    if L >= S:
        pad = L - S
        cache = KVCache(
            k=jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            v=jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            slot_pos=jnp.pad(pos1d.astype(jnp.int32), (0, pad),
                             constant_values=-1),
        )
    else:  # ring buffer keeps the last L positions at slot pos % L
        keep = slice(S - L, S)
        kk, vv, pp = k[:, keep], v[:, keep], pos1d[keep].astype(jnp.int32)
        slots = pp % L
        order = jnp.argsort(slots)
        cache = KVCache(k=kk[:, order], v=vv[:, order], slot_pos=pp[order])
    return out, cache


def attention_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig, *,
                     position: jnp.ndarray, cache: KVCache,
                     window: int = 0):
    """Single-token decode. x: (B, 1, d); position: scalar int32.

    Returns (out (B,1,d), new_cache). The cache is a ring buffer when
    `window > 0` (slot = position % window), else direct-indexed.
    """
    cdt = x.dtype
    B = x.shape[0]
    L = cache.k.shape[1]
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"].astype(cdt))
    k_new = jnp.einsum("bsd,dkh->bskh", x, p["wk"].astype(cdt))
    v_new = jnp.einsum("bsd,dkh->bskh", x, p["wv"].astype(cdt))
    pos = jnp.asarray(position, jnp.int32)
    q = rope(q, pos[None, None].astype(jnp.float32) * jnp.ones((B, 1)), cfg.rope_theta)
    k_new = rope(k_new, pos[None, None].astype(jnp.float32) * jnp.ones((B, 1)),
                 cfg.rope_theta)

    slot = jnp.where(window > 0, pos % L, pos)
    k = jax.lax.dynamic_update_slice(cache.k, k_new, (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache.v, v_new, (0, slot, 0, 0))
    slot_pos = jax.lax.dynamic_update_slice(cache.slot_pos, pos[None], (slot,))

    scores = _gqa_scores(q, k)                                   # (B,K,G,1,L)
    valid = (slot_pos >= 0) & (slot_pos <= pos)
    if window:
        valid &= slot_pos > pos - window
    probs = _masked_softmax(scores, valid[None, None, None, None, :]).astype(cdt)
    out = _gqa_out(probs, v)
    out = jnp.einsum("bsnh,nhd->bsd", out, p["wo"].astype(cdt))
    return out, KVCache(k, v, slot_pos)


def init_attention_params(key, cfg: ModelConfig, dtype) -> dict:
    d, N, K, H = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, N, H)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, K, H)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, K, H)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (N, H, d)) * (N * H) ** -0.5).astype(dtype),
    }
