"""Explicit all-to-all MoE dispatch via shard_map (§Perf H2 iter 3).

GSPMD lowers the gather/scatter dispatch of `moe.moe_apply` to
replicate + all-reduce of the full token buffer per layer (measured
~5 GB/layer on qwen3-30B). This variant makes the communication explicit
— the paper's own lesson: one minimal collective instead of many
compiler-inferred ones.

Per device (tokens sharded over `data`, experts over `model`):
  1. local router top-k;
  2. pack tokens into a fixed (E, C_loc, d) send buffer
     (C_loc = ceil(T_loc * k * cf / E) — per-source-device capacity);
  3. `all_to_all` over the expert axis: -> (E_loc, n_model * C_loc, d);
  4. local expert FFN on resident experts;
  5. `all_to_all` back + local weighted combine.

Communication per device per layer = 2 x E * C_loc * d (send+return),
independent of the data-axis world size — vs the scatter-add fallback's
O(T * d) all-reduce.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.substrate import all_to_all_experts, shard_map

from repro.models.config import ModelConfig
from repro.models.moe import _expert_ffn


def _local_pack(xf, logits, E, K, C, cdt):
    """Greedy capacity-bounded packing on one device.

    xf: (T, d); returns send buffer (E, C, d), weight/slot bookkeeping."""
    T, d = xf.shape
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)
    flat_w = top_w.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, 0) - 1,
                              flat_e[:, None], 1)[:, 0]
    tok = jnp.arange(T * K) // K
    idx = jnp.zeros((E, C), jnp.int32).at[flat_e, pos].set(tok, mode="drop")
    wgt = jnp.zeros((E, C), jnp.float32).at[flat_e, pos].set(flat_w,
                                                             mode="drop")
    valid = jnp.zeros((E, C), bool).at[flat_e, pos].set(True, mode="drop")
    send = jnp.take(xf, idx.reshape(-1), 0).reshape(E, C, d).astype(cdt)
    send = send * valid[..., None].astype(cdt)
    return send, idx, wgt, valid, probs


def moe_apply_a2a(p: dict, x: jnp.ndarray, cfg: ModelConfig, mesh: Mesh,
                  *, dp_axis="data", ep_axis: str = "model"
                  ) -> Tuple[jnp.ndarray, dict]:
    """Drop-in MoE layer with explicit all-to-all expert parallelism.

    x: (B, S, d) sharded P(dp_axis, None, None); expert weights sharded
    P(ep_axis, ...). Requires E % mesh[ep_axis] == 0.
    """
    mc = cfg.moe
    E, K = mc.n_experts, mc.top_k
    n_ep = mesh.shape[ep_axis]
    assert E % n_ep == 0

    def body(x_blk, router, experts):
        # x_blk: (B_loc, S, d) — this device's tokens (replicated over ep)
        B_loc, S, d = x_blk.shape
        T = B_loc * S
        C = max(1, math.ceil(T * K * mc.capacity_factor / E))
        cdt = x_blk.dtype
        xf = x_blk.reshape(T, d)
        logits = jnp.einsum("td,de->te", xf.astype(jnp.float32),
                            router.astype(jnp.float32))
        send, idx, wgt, valid, probs = _local_pack(xf, logits, E, K, C, cdt)

        # ---- the explicit communication: one a2a out, one a2a back ----
        recv = all_to_all_experts(send.reshape(n_ep, E // n_ep, C, d),
                                  ep_axis)
        # recv: (n_ep, E_loc, C, d) — tokens from every source device for
        # the experts resident here
        E_loc = E // n_ep
        ye = _expert_ffn(experts,
                         recv.transpose(1, 0, 2, 3).reshape(E_loc, n_ep * C, d),
                         cfg.mlp_act)
        back = ye.reshape(E_loc, n_ep, C, d).transpose(1, 0, 2, 3)
        ret = all_to_all_experts(back, ep_axis)
        ret = ret.reshape(E, C, d)                     # this device's slots

        contrib = ret * (wgt * valid)[..., None].astype(cdt)
        out = jnp.zeros((T, d), cdt).at[idx.reshape(-1)].add(
            contrib.reshape(-1, d))

        f = jnp.mean(jax.nn.one_hot(jnp.argmax(logits, -1), E,
                                    dtype=jnp.float32), axis=0)
        aux = E * jnp.sum(f * jnp.mean(probs, axis=0))
        return out.reshape(B_loc, S, d), aux

    # expert weights arrive sharded over ep; everything else replicated
    expert_specs = jax.tree.map(lambda _: P(ep_axis, None, None),
                                p["experts"])
    fn = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axis, None, None), P(), expert_specs),
        out_specs=(P(dp_axis, None, None), P()))
    out, aux = fn(x, p["router"], p["experts"])
    if mc.n_shared:
        from repro.models.mlp import mlp_apply
        B, S, d = x.shape
        out = out + mlp_apply(p["shared"], x, cfg.mlp_act)
    return out, {"moe_aux_loss": aux, "moe_z_loss": jnp.zeros(()),
                 "moe_drop_frac": jnp.zeros(())}
