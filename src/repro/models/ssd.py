"""Mamba-2 SSD (state-space duality) layer, arXiv:2405.21060.

Training/prefill uses the chunked SSD algorithm: the sequence is split
into chunks of length Q; within-chunk terms are computed as masked
"attention-like" einsums (the dual quadratic form, MXU-friendly), and
chunk-boundary states are carried with a short sequential scan — O(L)
overall with matmul-dominated inner work.

Decode carries the (B, H, P, N) SSM state and a depthwise-conv window.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.rglru import _causal_depthwise_conv


class SsdCache(NamedTuple):
    state: jnp.ndarray      # (B, H, P, N) float32
    conv: jnp.ndarray       # (B, k-1, conv_dim)


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < k <= i} a[..., k].

    a: (..., Q) -> (..., Q, Q), lower-triangular validity.
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    i = jnp.arange(Q)
    mask = i[:, None] >= i[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dtA: jnp.ndarray, B: jnp.ndarray,
                C: jnp.ndarray, chunk: int,
                init_state: jnp.ndarray | None = None):
    """SSD core. x: (b, l, h, p) [already multiplied by dt], dtA: (b, l, h),
    B, C: (b, l, h, n) (groups pre-broadcast to heads). Returns (y, final_state).
    """
    b, l, h, p = x.shape
    n = B.shape[-1]
    orig_l = l
    if l % chunk:                       # pad to a chunk multiple; dtA = 0 and
        pad = chunk - l % chunk         # B = 0 on padding leaves state exact
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtA = jnp.pad(dtA, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = x.shape[1]
    c = l // chunk
    f32 = jnp.float32

    xr = x.reshape(b, c, chunk, h, p)
    Ar = dtA.reshape(b, c, chunk, h).astype(f32)
    Br = B.reshape(b, c, chunk, h, n)
    Cr = C.reshape(b, c, chunk, h, n)

    A_cum = jnp.cumsum(Ar, axis=2)                               # (b,c,q,h)
    # ---- intra-chunk (dual quadratic form) ----
    L = jnp.exp(_segsum(Ar.transpose(0, 1, 3, 2)))               # (b,c,h,q,q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", Cr, Br)            # (b,c,h,q,k)
    y_diag = jnp.einsum("bchqk,bckhp->bcqhp",
                        (scores * L).astype(x.dtype), xr)

    # ---- chunk states ----
    decay_states = jnp.exp(A_cum[:, :, -1:, :] - A_cum)          # (b,c,q,h)
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchpn", Br,
                        decay_states.astype(x.dtype), xr)        # (b,c,h,p,n)

    # ---- inter-chunk recurrence (sequential over chunks) ----
    chunk_decay = jnp.exp(A_cum[:, :, -1, :]).astype(f32)        # (b,c,h)
    s0 = jnp.zeros((b, h, p, n), f32) if init_state is None else init_state

    def step(carry, inp):
        dec, st = inp                                            # (b,h), (b,h,p,n)
        new = carry * dec[..., None, None] + st.astype(f32)
        return new, carry                                        # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,c,h,p,n)

    # ---- inter-chunk output ----
    state_decay = jnp.exp(A_cum).astype(x.dtype)                 # (b,c,q,h)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp", Cr,
                       prev_states.astype(x.dtype), state_decay)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y[:, :orig_l], final


def _split_proj(p: dict, x: jnp.ndarray, cfg: ModelConfig):
    sc = cfg.ssd
    d_in = sc.n_heads * sc.head_dim
    gn = sc.n_groups * sc.state_dim
    cdt = x.dtype
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(cdt))
    z, xin, Bc, Cc, dt = jnp.split(
        zxbcdt, [d_in, 2 * d_in, 2 * d_in + gn, 2 * d_in + 2 * gn], axis=-1)
    return z, xin, Bc, Cc, dt


def _prep(p: dict, xin, Bc, Cc, dt, cfg: ModelConfig):
    sc = cfg.ssd
    b, l, _ = xin.shape
    H, P, G, N = sc.n_heads, sc.head_dim, sc.n_groups, sc.state_dim
    f32 = jnp.float32
    dt = jax.nn.softplus(dt.astype(f32) + p["dt_bias"].astype(f32))   # (b,l,H)
    A = -jnp.exp(p["A_log"].astype(f32))                              # (H,)
    dtA = dt * A[None, None, :]
    xh = xin.reshape(b, l, H, P)
    rep = H // G
    Bh = jnp.repeat(Bc.reshape(b, l, G, N), rep, axis=2)
    Ch = jnp.repeat(Cc.reshape(b, l, G, N), rep, axis=2)
    x_dt = xh * dt[..., None].astype(xh.dtype)
    return x_dt, dtA, Bh, Ch, xh


def ssd_block_train(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                    return_state: bool = False):
    """Full-sequence Mamba-2 block. x: (B, S, d_model)."""
    sc = cfg.ssd
    z, xin, Bc, Cc, dt = _split_proj(p, x, cfg)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc, p["conv_w"]))
    d_in = sc.n_heads * sc.head_dim
    gn = sc.n_groups * sc.state_dim
    xin, Bc, Cc = jnp.split(xbc, [d_in, d_in + gn], axis=-1)

    x_dt, dtA, Bh, Ch, xh = _prep(p, xin, Bc, Cc, dt, cfg)
    y, final = ssd_chunked(x_dt, dtA, Bh, Ch, sc.chunk)
    y = y + xh * p["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(x.shape[0], x.shape[1], d_in)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(y.dtype))
    if return_state:
        return out, final
    return out


def ssd_block_decode(p: dict, x: jnp.ndarray, cfg: ModelConfig,
                     cache: SsdCache) -> Tuple[jnp.ndarray, SsdCache]:
    """One-token decode. x: (B, 1, d_model); recurrent state update."""
    sc = cfg.ssd
    f32 = jnp.float32
    z, xin, Bc, Cc, dt = _split_proj(p, x, cfg)
    xbc_in = jnp.concatenate([xin, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(_causal_depthwise_conv(xbc_in, p["conv_w"],
                                             carry=cache.conv))
    conv_new = jnp.concatenate([cache.conv[:, 1:],
                                xbc_in.astype(cache.conv.dtype)], axis=1)
    d_in = sc.n_heads * sc.head_dim
    gn = sc.n_groups * sc.state_dim
    xin, Bc, Cc = jnp.split(xbc, [d_in, d_in + gn], axis=-1)

    x_dt, dtA, Bh, Ch, xh = _prep(p, xin, Bc, Cc, dt, cfg)
    # h' = exp(dtA) h + B (dt*x) ;  y = C h' + D x
    dA = jnp.exp(dtA[:, 0]).astype(f32)                            # (B,H)
    outer = jnp.einsum("bhp,bhn->bhpn", x_dt[:, 0].astype(f32),
                       Bh[:, 0].astype(f32))
    state = cache.state * dA[..., None, None] + outer
    y = jnp.einsum("bhn,bhpn->bhp", Ch[:, 0].astype(f32), state)
    y = y.astype(x.dtype) + xh[:, 0] * p["D"].astype(x.dtype)[None, :, None]
    y = y.reshape(x.shape[0], 1, d_in)
    y = y * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(y.dtype))
    return out, SsdCache(state=state, conv=conv_new)


def init_ssd_cache(batch: int, cfg: ModelConfig) -> SsdCache:
    sc = cfg.ssd
    conv_dim = sc.n_heads * sc.head_dim + 2 * sc.n_groups * sc.state_dim
    cdt = jnp.dtype(cfg.compute_dtype)
    return SsdCache(
        state=jnp.zeros((batch, sc.n_heads, sc.head_dim, sc.state_dim),
                        jnp.float32),
        conv=jnp.zeros((batch, sc.conv_kernel - 1, conv_dim), cdt),
    )


def init_ssd_params(key, cfg: ModelConfig, dtype) -> dict:
    sc = cfg.ssd
    d = cfg.d_model
    d_in = sc.n_heads * sc.head_dim
    gn = sc.n_groups * sc.state_dim
    proj_out = 2 * d_in + 2 * gn + sc.n_heads
    conv_dim = d_in + 2 * gn
    keys = jax.random.split(key, 4)
    return {
        "w_in": (jax.random.normal(keys[0], (d, proj_out)) * d ** -0.5).astype(dtype),
        "conv_w": (jax.random.normal(keys[1], (sc.conv_kernel, conv_dim))
                   * sc.conv_kernel ** -0.5).astype(dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(keys[2], (sc.n_heads,),
                                       minval=jnp.log(1e-3), maxval=jnp.log(1e-1))))
        ).astype(dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, sc.n_heads)).astype(dtype),
        "D": jnp.ones((sc.n_heads,), dtype),
        "w_out": (jax.random.normal(keys[3], (d_in, d)) * d_in ** -0.5).astype(dtype),
    }
