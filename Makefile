PY ?= python
# src for the repro package, . for the benchmarks package (fig1 imports
# benchmarks.paper_common)
export PYTHONPATH := src:.:$(PYTHONPATH)

.PHONY: test test-stats test-cpu8 test-chaos lint bench-smoke bench-json \
	check-regression bench-stream-smoke bench-serve-smoke smoke-examples \
	obs-report

# default flow: the static-analysis pass first (fails in seconds, before
# any kernel test runs), then the full pytest suite (which includes the
# statistical and chaos tiers below) plus the perf-floor +
# guarded-ingest-overhead gate on the committed bench JSON, then the
# seeded chaos schedule end to end
test: lint
	$(PY) -m pytest -q
	$(PY) benchmarks/check_regression.py
	$(PY) tools/chaos.py

# repo-native invariant linter + static Pallas tiling/VMEM contract
# checker + concurrency contract checker (DESIGN.md sections 13 and 17
# for the RLxxx codes). The full run already includes all three
# engines; the explicit --concurrency and --cache legs re-run the two
# stdlib-only engines standalone, proving each stays importable and
# clean with no jax in the environment (tests/test_invariants.py pins
# the no-jax property with subprocess probes). --cache is a no-op when
# .cache/autotune.json does not exist.
lint:
	$(PY) -m tools.repro_lint src benchmarks
	$(PY) -m tools.repro_lint --concurrency src benchmarks
	$(PY) -m tools.repro_lint --cache

# statistical correctness tier alone: the paper's claims (exact support
# recovery, debiased error vs the centralized oracle) plus the golden
# figure-driver smoke points
test-stats:
	$(PY) -m pytest -q tests/test_statistical_recovery.py \
	    tests/test_figures_smoke.py

# resilience tier alone: the fault-injection suite (poisoned batches,
# forced refit divergence, torn checkpoints, SIGKILL mid-ingest) plus
# the seeded end-to-end chaos schedule from tools/chaos.py
test-chaos:
	$(PY) -m pytest -q tests/test_chaos.py
	$(PY) tools/chaos.py

# sharded DSML / SPMD paths with 8 forced host devices (the in-test
# subprocess probes force their own device count; this job exercises the
# same paths directly in-process on CI CPU workers)
test-cpu8:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) -m pytest -q tests/test_distributed.py tests/test_moe_a2a.py \
	    tests/test_batched_solver.py tests/test_stream.py \
	    tests/test_serve.py

bench-smoke:
	$(PY) benchmarks/kernels_bench.py
	$(PY) benchmarks/communication.py
	$(PY) benchmarks/fig1_regression.py --smoke
	$(PY) benchmarks/fig2_classification.py --smoke
	$(PY) benchmarks/largep_logistic.py --smoke

# machine-readable kernel bench rows, tracked across PRs; the committed
# BENCH_kernels.json is the perf baseline check-regression gates on
bench-json:
	$(PY) -m benchmarks.run --only kern --json-out BENCH_kernels.json

check-regression:
	$(PY) benchmarks/check_regression.py

# streaming subsystem: ingest throughput + warm-vs-cold refit, with the
# sharded data x task accumulator exercised on 8 forced host devices
bench-stream-smoke:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) benchmarks/stream_bench.py --smoke

# serving front rows (request p99 under load, ingest-while-serving
# throughput) as the committed machine-readable artifact check-regression
# gates with SERVE_BOUNDS
bench-serve-smoke:
	$(PY) -m benchmarks.run --only serve --json-out BENCH_serve.json

smoke-examples:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) examples/stream_online.py --smoke
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) examples/serve_front.py --smoke
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) examples/quickstart.py

# telemetry quick look: run the streaming bench instrumented, then
# summarize the snapshot it wrote (experiments/obs/stream_smoke.json;
# a .trace.json Chrome trace lands next to it — open in Perfetto)
obs-report:
	XLA_FLAGS="--xla_force_host_platform_device_count=8" \
	$(PY) benchmarks/stream_bench.py --smoke \
	    --obs-out experiments/obs/stream_smoke.json
	$(PY) -m repro.obs experiments/obs/stream_smoke.json
