"""Batched serving demo: prefill a batch of prompts into a KV cache, then
greedy-decode new tokens (the serve_step the dry-run lowers at 32k/500k).

    PYTHONPATH=src python examples/serve_batch.py --arch mamba2-1.3b
"""
import argparse
import time

import jax

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.serving.engine import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = smoke(get_config(args.arch))
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"serving {cfg.name} (reduced): batch={args.batch}, "
          f"prompt={args.prompt_len}, generate={args.new_tokens}")

    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    fe = None
    if cfg.frontend:
        fe = 0.1 * jax.random.normal(jax.random.PRNGKey(2),
                                     (args.batch, cfg.n_frontend_tokens,
                                      cfg.d_model))
    gen = jax.jit(lambda p: greedy_generate(params, cfg, p,
                                            steps=args.new_tokens,
                                            frontend=fe))
    t0 = time.time()
    out = jax.block_until_ready(gen(prompt))
    t_compile = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(gen(prompt))
    t_run = time.time() - t0
    tok_s = args.batch * args.new_tokens / t_run
    print(f"compile {t_compile:.1f}s; decode {t_run:.2f}s "
          f"({tok_s:.0f} tok/s on CPU)")
    print("sample continuation token ids:", out[0, args.prompt_len:][:16].tolist())


if __name__ == "__main__":
    main()
