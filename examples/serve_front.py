"""Serve predictions while the model keeps learning: a background
thread folds a drifting stream into `StreamingDsmlService` (refits
adopt new model generations by atomic snapshot swap) while a
`ServingFront` microbatches predict requests from a pool of
closed-loop client threads.

    PYTHONPATH=src python examples/serve_front.py [--smoke] [--clients 4]

Watch for: client latency stays flat through refits (readers hold
immutable `ModelGeneration` snapshots — adoption never blocks or
tears a predict), every response carries the generation that served
it, and the generation counter climbs while traffic flows.
"""
import argparse
import threading
import time

import numpy as np

from repro import obs
from repro.stream import ServingFront, StreamingDsmlService


def make_stream(rng, m, p, s, n_chunk, chunks):
    """A drifting regression stream: the true coefficients take a
    random walk, so the drift-aware service keeps refitting."""
    B = np.zeros((m, p), np.float32)
    B[:, rng.choice(p, s, replace=False)] = rng.standard_normal((m, s))
    for _ in range(chunks):
        B += 0.02 * rng.standard_normal(B.shape).astype(np.float32)
        X = rng.standard_normal((m, n_chunk, p)).astype(np.float32)
        y = (np.einsum("tnp,tp->tn", X, B)
             + 0.1 * rng.standard_normal((m, n_chunk))).astype(np.float32)
        yield X, y


def main(argv=None):
    """Run the demo; returns the headline metrics dict (request count,
    latency quantiles, generations served) for smoke assertions."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--p", type=int, default=128)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--chunk-size", type=int, default=256)
    ap.add_argument("--chunks", type=int, default=24)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--smoke", action="store_true", help="tiny CI sizes")
    args = ap.parse_args(argv)
    if args.smoke:
        args.m, args.p, args.s = 4, 48, 5
        args.chunk_size, args.chunks = 64, 8

    rng = np.random.default_rng(0)
    svc = StreamingDsmlService(
        args.m, args.p, lam=0.4, mu=0.2, Lam=1.0, decay=0.9,
        refit_every=args.chunk_size, max_refit_interval=2 * args.chunk_size,
        lasso_iters=200, debias_iters=300, refit_tol=1e-5)
    stream = make_stream(rng, args.m, args.p, args.s,
                         args.chunk_size, args.chunks)
    svc.ingest(*next(stream))           # first model + jit warmup

    def feeder():
        for X, y in stream:
            svc.ingest(X, y)

    stop = threading.Event()
    gens_seen = set()
    latencies = []
    lock = threading.Lock()

    def client():
        q = rng.standard_normal(args.p).astype(np.float32)
        while not stop.is_set():
            t0 = time.perf_counter()
            res = front.predict(q, timeout=30)
            dt = (time.perf_counter() - t0) * 1e3
            with lock:
                gens_seen.add(res.generation)
                latencies.append(dt)

    with ServingFront(svc, max_batch=64, max_delay_ms=2.0) as front:
        front.predict(np.zeros(args.p, np.float32))   # compile first
        feed = threading.Thread(target=feeder)
        pool = [threading.Thread(target=client)
                for _ in range(args.clients)]
        feed.start()
        for c in pool:
            c.start()
        feed.join()                     # serve until the stream runs dry
        stop.set()
        for c in pool:
            c.join()
        q = front.latency_quantiles() or {}   # None under REPRO_OBS=0
        p50, p99 = q.get(0.5, 0.0), q.get(0.99, 0.0)

    metrics = {
        "requests": len(latencies),
        "client_p50_ms": float(np.percentile(latencies, 50)),
        "client_p99_ms": float(np.percentile(latencies, 99)),
        "front_p50_ms": p50,
        "front_p99_ms": p99,
        "generations_served": len(gens_seen),
        "final_generation": svc.generation,
        "batches": obs.counter_total("serve.batches"),
    }
    print(f"served {metrics['requests']} requests over "
          f"{metrics['generations_served']} model generations "
          f"(final gen {metrics['final_generation']})")
    print(f"client latency p50={metrics['client_p50_ms']:.2f}ms "
          f"p99={metrics['client_p99_ms']:.2f}ms; front-side "
          f"p50={p50:.2f}ms p99={p99:.2f}ms over "
          f"{metrics['batches']:.0f} microbatches")
    return metrics


if __name__ == "__main__":
    main()
