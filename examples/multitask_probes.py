"""The paper's technique as a framework feature: communication-efficient
multi-task sparse probes on frozen backbone features (DESIGN.md §5).

Four "machines" each own a task (their own labelled data); the backbone
is shared and frozen. DSML recovers the common sparse support over
feature dimensions with ONE round of communication.

    PYTHONPATH=src python examples/multitask_probes.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config, smoke
from repro.models import init_params
from repro.multitask import (
    probe_predict, sparse_probe_fit, synthetic_probe_tasks,
)


def main():
    cfg = smoke(get_config("granite-3-2b")).replace(
        compute_dtype="float32", param_dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    print(f"backbone: {cfg.name} (reduced) d_model={cfg.d_model}")

    data, support = synthetic_probe_tasks(jax.random.PRNGKey(1), params,
                                          cfg, m=4, n=96, s_active=6)
    print(f"tasks=4, samples/task=96, active feature dims={int(support.sum())}")

    res = sparse_probe_fit(data)
    tp = int(jnp.sum(res.support & support))
    fp = int(jnp.sum(res.support & ~support))
    print(f"recovered support: {tp}/{int(support.sum())} true dims, "
          f"{fp} false positives")

    pred = probe_predict(res, data.features)
    r2 = 1 - float(jnp.var(pred - data.targets) / jnp.var(data.targets))
    print(f"fit R^2 = {r2:.3f}")
    d = cfg.d_model
    print(f"communication: one round of {d} floats per task "
          f"(vs shipping {data.features.shape[1]}x{d} features per task)")


if __name__ == "__main__":
    main()
