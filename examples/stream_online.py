"""Online DSML over a non-stationary stream: ingest minibatches, let the
drift-aware service decide when to refit, and watch it re-acquire the
support after a mid-stream regime shift.

    PYTHONPATH=src python examples/stream_online.py [--smoke] [--decay 0.7]

With multiple devices (e.g. XLA_FLAGS=--xla_force_host_platform_device_count=8)
ingestion runs SPMD over a data x task mesh via `stream.accumulate`.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import obs
from repro.core import ar_covariance, hamming, sample_coefficients
from repro.stream import StreamingDsmlService


def make_regime(key, p, m, s, rho=0.5):
    Sigma = ar_covariance(p, rho)
    chol = jnp.linalg.cholesky(Sigma + 1e-9 * jnp.eye(p))
    B, support = sample_coefficients(key, p, m, s, low=0.3, high=1.0)
    return chol, B, support


def draw_chunk(key, chol, B, n, sigma=1.0):
    m = B.shape[1]
    p = B.shape[0]
    k_x, k_e = jax.random.split(key)
    Xs = jax.random.normal(k_x, (m, n, p)) @ chol.T
    ys = jnp.einsum("tnp,pt->tn", Xs, B) + sigma * jax.random.normal(k_e, (m, n))
    return Xs, ys


def main(argv=None):
    """Run the stream demo; returns the headline metrics dict so the
    golden-band smoke test (tests/test_figures_smoke.py) can pin them —
    same `--smoke` + committed-band pattern as the figure drivers."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--p", type=int, default=128)
    ap.add_argument("--s", type=int, default=8)
    ap.add_argument("--chunk-size", type=int, default=256)
    ap.add_argument("--chunks", type=int, default=16)
    ap.add_argument("--decay", type=float, default=0.7,
                    help="exponential forgetting per chunk (1.0 = none)")
    ap.add_argument("--shift-at", type=float, default=0.5,
                    help="fraction of the stream after which the true "
                         "support moves")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes for CI")
    ap.add_argument("--obs-out", default=None, metavar="PATH",
                    help="write the telemetry snapshot (and a "
                         ".trace.json Chrome trace next to it)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.m, args.p, args.s = 4, 48, 5
        args.chunk_size, args.chunks = 64, 8

    base = float(jnp.sqrt(jnp.log(float(args.p)) / args.chunk_size))
    mesh = None
    if jax.device_count() > 1 and args.m % 2 == 0 \
            and args.chunk_size % (jax.device_count() // 2) == 0:
        from repro.substrate import data_task_mesh
        mesh = data_task_mesh(n_task=2)
        print(f"ingesting SPMD over mesh {dict(mesh.shape)}")

    svc = StreamingDsmlService(
        args.m, args.p, lam=4 * base, mu=base, Lam=1.0,
        decay=args.decay, refit_every=2 * args.chunk_size,
        lasso_iters=400, debias_iters=400, chunk_n=args.chunk_size,
        mesh=mesh)

    key = jax.random.PRNGKey(0)
    k_a, k_b, key = jax.random.split(key, 3)
    chol, B, support = make_regime(k_a, args.p, args.m, args.s)
    shift_chunk = int(args.shift_at * args.chunks)
    print(f"stream: m={args.m} tasks, p={args.p}, s={args.s}, "
          f"{args.chunks} chunks x {args.chunk_size} samples, "
          f"decay={args.decay}, shift at chunk {shift_chunk}")

    refits_during_stream = 0
    for i in range(args.chunks):
        if i == shift_chunk:
            chol, B, support = make_regime(k_b, args.p, args.m, args.s)
            print(f"--- regime shift at chunk {i}: new support ---")
        key, k = jax.random.split(key)
        Xs, ys = draw_chunk(k, chol, B, args.chunk_size)
        t0 = time.perf_counter()
        info = svc.ingest(Xs, ys)
        dt = (time.perf_counter() - t0) * 1e3
        if info is not None:
            h = int(hamming(svc.state.support, support))
            err = float(jnp.max(jnp.abs(svc.state.beta_tilde - B.T)))
            refits_during_stream += 1
            print(f"[chunk {i:3d} | eff samples {svc.samples_seen:7.0f}] "
                  f"refit gen={int(info.generation)} |S|={int(info.support_size)} "
                  f"jaccard={float(info.jaccard):.2f} hamming={h} "
                  f"est_err={err:.3f} ({dt:.0f} ms incl. ingest)")

    svc.refit()
    h = int(hamming(svc.state.support, support))
    err = float(jnp.max(jnp.abs(svc.state.beta_tilde - B.T)))
    # serve one scoring round so the trace timeline shows the full
    # ingest -> refit -> predict lifecycle of the service
    jax.block_until_ready(svc.predict(Xs))
    print(f"final: generation {svc.generation}, support hamming vs current "
          f"regime = {h} (decay {'forgets' if args.decay < 1 else 'keeps'} "
          f"the old regime)")

    # telemetry-derived headlines (None-safe: REPRO_OBS=0 zeroes them)
    ing = obs.hist_stats("stream.ingest.ms")
    ref_ms = obs.hist_stats("stream.refit.ms")
    ing_rows = obs.counter_total("stream.ingest.rows")
    obs_rate = (ing_rows / (ing["sum"] * 1e-3)
                if ing and ing["sum"] > 0 else 0.0)
    if args.obs_out:
        from repro.obs import export as obs_export
        obs_export.write_snapshot(
            args.obs_out,
            meta={"example": "stream_online", "smoke": bool(args.smoke)})
        base = args.obs_out[:-5] if args.obs_out.endswith(".json") \
            else args.obs_out
        obs_export.write_chrome_trace(base + ".trace.json")
        print(f"wrote {args.obs_out} and {base}.trace.json")
    return {
        "final_hamming": h,
        "final_est_err": err,
        "generations": int(svc.generation),
        "refits_during_stream": refits_during_stream,
        "samples_seen": float(svc.samples_seen),
        "obs_ingest_rows_per_s": obs_rate,
        "obs_refit_latency_ms": ref_ms["mean"] if ref_ms else 0.0,
        "obs_refits_recorded": ref_ms["count"] if ref_ms else 0,
    }


if __name__ == "__main__":
    main()
