"""Quickstart: DSML (paper Algorithm 1) vs local lasso / group lasso on
synthetic shared-support multi-task regression.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (
    dsml_fit, estimation_error, gen_regression, group_lasso, hamming,
    prediction_error, support_of,
)


def main():
    key = jax.random.PRNGKey(0)
    m, n, p, s = 10, 100, 200, 10
    print(f"tasks m={m}, samples/task n={n}, dims p={p}, support s={s}")
    data = gen_regression(key, m=m, n=n, p=p, s=s, signal_low=0.3)

    base = float(jnp.sqrt(jnp.log(float(p)) / n))
    res = dsml_fit(data.Xs, data.ys, lam=4 * base, mu=base, Lam=1.0)

    def report(name, B_hat):
        print(f"{name:12s} hamming={int(hamming(support_of(B_hat, 1e-3), data.support)):3d}  "
              f"est_err={float(estimation_error(B_hat, data.B)):7.2f}  "
              f"pred_err={float(prediction_error(B_hat, data.B, data.Sigma)):7.4f}")

    report("local lasso", res.beta_local.T)
    report("group lasso", group_lasso(data.Xs, data.ys, 0.3))
    report("DSML", res.beta_tilde.T)
    print(f"\nDSML support correct: {bool(jnp.all(res.support == data.support))}")
    print(f"communication: {m} x {p} floats up, {p} bits down "
          f"(vs {m}x{n}x{p} floats to centralize)")


if __name__ == "__main__":
    main()
