"""End-to-end training driver: train a small LM from the zoo on synthetic
data and watch the loss fall.

CPU demo (default, ~25M params):
    PYTHONPATH=src python examples/train_lm.py --steps 30

The ~100M configuration used for the checked-in loss curve:
    PYTHONPATH=src python examples/train_lm.py --d-model 768 --layers 12 \
        --steps 300 --batch 8 --seq 512
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.synth_tokens import synthetic_lm_batches
from repro.models import Batch
from repro.training.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    args = ap.parse_args()

    cfg = get_config(args.arch).replace(
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 128), n_kv_heads=2,
        head_dim=64, d_ff=4 * args.d_model, vocab=args.vocab)
    n_params = cfg.param_count()
    print(f"arch={cfg.name} (reduced) params={n_params/1e6:.1f}M")

    state = init_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(make_train_step(cfg, peak_lr=args.lr, warmup=20,
                                   total_steps=args.steps,
                                   microbatches=args.microbatches))

    batches = synthetic_lm_batches(jax.random.PRNGKey(1), vocab=cfg.vocab,
                                   batch=args.batch, seq=args.seq)
    t0 = time.time()
    for i, batch in zip(range(args.steps), batches):
        state, metrics = step(state, batch)
        if i % max(1, args.steps // 10) == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss={float(metrics['loss']):.4f}  "
                  f"grad_norm={float(metrics['grad_norm']):.3f}  "
                  f"lr={float(metrics['lr']):.2e}  "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    print("done.")


if __name__ == "__main__":
    main()
